//! Fused device acceptance (DESIGN.md §11) over the analytic simulator:
//! the scheduler's fused fast path — policy decision on "device", compact
//! acceptance back — must be **token-identical** to the host-side
//! `Policy::select` path for every fusible policy, across seeds and batch
//! sizes, including the argmax-fallback tie-break on equal confidences.

use osdt::cache::CacheConfig;
use osdt::decode::{DecodeResult, Engine, ForwardModel, StepScheduler};
use osdt::policy::{
    Calibrator, DynamicMode, FactorThreshold, HostTraced, Metric, Osdt,
    PlanContext, Policy, SequentialTopK, StaticThreshold, StepContext, StepRule,
};
use osdt::runtime::{accept_rows, AcceptRule, ConfOut};
use osdt::sim::SimModel;
use osdt::util::prop;
use osdt::util::rng::Rng;

const MASK: u32 = 1;

/// Build the policy under test; OSDT calibrates on an uncached decode.
fn policy_for(kind: u64, x: f64, m: &SimModel) -> Box<dyn Policy> {
    match kind {
        0 => Box::new(StaticThreshold::new(0.4 + x * 0.55)),
        1 => Box::new(FactorThreshold::new(0.5 + x * 0.5)),
        _ => {
            let engine = Engine::new(m);
            let cal = engine
                .decode(m.layout_from_seed(0), &StaticThreshold::new(0.9))
                .unwrap();
            let prof = Calibrator::calibrate(&cal.trace, DynamicMode::Block, Metric::Q1);
            Box::new(Osdt::from_profile(prof, 0.5 + x * 0.5, x * 0.3))
        }
    }
}

#[test]
fn prop_fused_decode_token_identical_to_host_path() {
    // policies × seeds × batch sizes: decoding with the fused path (plain
    // fusible policy) must match the host-decision path (HostTraced
    // wrapper) token for token, step for step, fallback for fallback
    prop::forall(
        "fused-vs-host-token-identity",
        25,
        |r: &mut Rng| {
            (
                r.next_u64(),
                r.below(3),
                r.next_f64(),
                1 + r.below(4) as usize,
            )
        },
        |&(seed, kind, x, n)| {
            let m = SimModel::qa_like(seed);
            let eng = Engine::with_kv_cache(&m);
            let fused_p = policy_for(kind, x, &m);
            let layouts: Vec<Vec<u32>> =
                (0..n).map(|i| m.layout_from_seed(seed ^ i as u64)).collect();

            // host path: HostTraced forces a HostFull plan per row
            let host: Vec<DecodeResult> = layouts
                .iter()
                .map(|l| {
                    let p = HostTraced(policy_for(kind, x, &m));
                    eng.decode(l.clone(), &p)
                })
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;

            // fused path, batched (the serving shape)
            let refs: Vec<&dyn Policy> =
                (0..n).map(|_| fused_p.as_ref()).collect();
            let fused = eng
                .decode_batch(layouts, &refs)
                .map_err(|e| e.to_string())?;

            for (i, (f, h)) in fused.iter().zip(&host).enumerate() {
                if f.tokens != h.tokens {
                    return Err(format!("seq {i}: tokens diverge"));
                }
                if f.steps != h.steps {
                    return Err(format!(
                        "seq {i}: {} vs {} steps",
                        f.steps, h.steps
                    ));
                }
                if f.fallback_steps != h.fallback_steps {
                    return Err(format!(
                        "seq {i}: fallback {} vs {}",
                        f.fallback_steps, h.fallback_steps
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_accept_rule_matches_policy_select_explain() {
    // the shared host-reference rule (what the device kernels implement)
    // must reproduce Policy::select_explain exactly on arbitrary rows —
    // confidences drawn from a coarse grid so exact ties are common and
    // the lowest-index tie-break is genuinely exercised
    prop::forall(
        "accept-rule-vs-select",
        400,
        |r: &mut Rng| {
            let w = 4 + r.below(28) as usize;
            let window: Vec<u32> = (0..w)
                .map(|_| if r.below(3) == 0 { 7 } else { MASK })
                .collect();
            let conf: Vec<f32> =
                (0..w).map(|_| r.below(8) as f32 / 8.0 + 0.05).collect();
            let arg: Vec<u32> = (0..w).map(|_| 4 + r.below(60) as u32).collect();
            let kind = r.below(2);
            let x = r.next_f64();
            (window, conf, arg, kind, x)
        },
        |(window, conf, arg, kind, x)| {
            let policy: Box<dyn Policy> = match *kind {
                0 => Box::new(StaticThreshold::new(*x)),
                _ => Box::new(FactorThreshold::new(*x)),
            };
            let rule = match policy.plan(&PlanContext { block: 0, step: 0 }).rule {
                StepRule::Threshold { tau } => AcceptRule::threshold(tau),
                StepRule::FactorMax { factor } => AcceptRule::factor_max(factor),
                StepRule::HostFull => return Err("policy not fusible".into()),
            };
            let masked: Vec<usize> = (0..window.len())
                .filter(|&i| window[i] == MASK)
                .collect();
            let local: Vec<f32> = masked.iter().map(|&i| conf[i]).collect();
            let (sel, fell) = policy.select_explain(&StepContext {
                block: 0,
                step: 0,
                conf: &local,
            });
            let want: Vec<(u32, u32)> = sel
                .iter()
                .map(|&i| (masked[i] as u32, arg[masked[i]]))
                .collect();

            let mut out = ConfOut::new(window.len());
            out.push_row(conf, arg);
            let got = accept_rows(&out, &[window.as_slice()], MASK, &[rule]);
            if got.row(0) != want.as_slice() {
                return Err(format!(
                    "pairs {:?} != select {:?} (rule {rule:?})",
                    got.row(0),
                    want
                ));
            }
            if got.fell_back(0) != fell {
                return Err(format!(
                    "fallback {} != {}",
                    got.fell_back(0),
                    fell
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn equal_confidence_fallback_picks_lowest_masked_index() {
    // deterministic tie case: every masked confidence equal, threshold
    // impossible — both paths must commit exactly the first masked position
    let window = [7u32, MASK, MASK, MASK];
    let conf = [0.9f32, 0.25, 0.25, 0.25];
    let arg = [11u32, 12, 13, 14];
    let mut out = ConfOut::new(4);
    out.push_row(&conf, &arg);
    let got = accept_rows(
        &out,
        &[&window],
        MASK,
        &[AcceptRule::threshold(f32::INFINITY)],
    );
    assert_eq!(got.row(0), &[(1, 12)]);
    assert!(got.fell_back(0));

    let p = StaticThreshold::new(1.0);
    let (sel, fell) = p.select_explain(&StepContext {
        block: 0,
        step: 0,
        conf: &[0.25, 0.25, 0.25],
    });
    assert_eq!(sel, vec![0], "host fallback ties to the lowest index");
    assert!(fell);
}

#[test]
fn fused_steady_state_covers_every_window_pass() {
    // with a fusible policy every in-block step takes the fused path; with
    // a host-full policy none do
    for (fusible, policy) in [
        (true, Box::new(StaticThreshold::new(0.9)) as Box<dyn Policy>),
        (false, Box::new(SequentialTopK::new(2)) as Box<dyn Policy>),
    ] {
        let m = SimModel::math_like(31);
        let mut sched: StepScheduler<'_, SimModel, Box<dyn Policy>> =
            StepScheduler::new(&m, CacheConfig::block_boundary(), m.max_batch());
        sched.admit(0, m.layout_from_seed(2), policy).unwrap();
        let mut window = 0;
        let mut fused = 0;
        while !sched.is_idle() {
            let r = sched.step().unwrap();
            window += r.window_passes;
            fused += r.fused_window_passes;
        }
        assert!(window > 0, "cached decode must take window steps");
        if fusible {
            assert_eq!(fused, window, "every window step must fuse");
        } else {
            assert_eq!(fused, 0, "host-full plans must never fuse");
        }
    }
}

#[test]
fn fused_accept_reports_compact_rows_through_the_model_contract() {
    // exercise ForwardModel::fwd_window_accept directly (the default
    // emulation SimModel uses): rows must agree with per-row fwd_window +
    // the host rule, and empty-mask rows must come back empty
    let m = SimModel::math_like(12);
    let cfg = m.config().clone();
    let layout = m.layout_from_seed(3);
    let (_, cache) = m.fwd_full_kv(&layout).unwrap();
    let start = cfg.block_range(0).start;
    let window: Vec<u32> = layout[cfg.block_range(0)].to_vec();
    let rules = [AcceptRule::threshold(0.8)];
    let got = m
        .fwd_window_accept(&[window.as_slice()], &[start], &[&cache], &rules)
        .unwrap();
    let conf = m.fwd_window(&window, start, &cache).unwrap();
    let want = accept_rows(&conf, &[window.as_slice()], cfg.mask_id, &rules);
    assert_eq!(got.len(), 1);
    assert_eq!(got.row(0), want.row(0));
    assert_eq!(got.fell_back(0), want.fell_back(0));
    assert!((got.step_mean(0) - want.step_mean(0)).abs() < 1e-6);
    assert!(!got.row(0).is_empty(), "fully masked block must commit");
}
