//! Integration tests of the full L3 serving stack (coordinator + server +
//! policies + decode) over the analytic simulator — fast, artifact-free,
//! exercising cross-module composition and concurrency.

use std::sync::Arc;
use std::time::Duration;

use osdt::cache::CacheConfig;
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::decode::{Engine, ForwardModel};
use osdt::model::fixtures::tiny_config;
use osdt::policy::{
    Calibrator, DynamicMode, Metric, Osdt, ProfileRecord, ProfileStore,
    SequentialTopK, StaticThreshold,
};
use osdt::server::{Client, Server};
use osdt::sim::SimModel;
use osdt::util::prop;
use osdt::util::rng::Rng;

fn sim_coordinator(workers: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(
            CoordinatorConfig {
                workers,
                max_batch: 4,
                // wide batching window: the simulator decodes in tens of
                // microseconds, so concurrent TCP arrivals need the worker
                // to hold its first admission for batches to form reliably
                batch_wait: Duration::from_millis(50),
                cache: CacheConfig::disabled(),
                ..CoordinatorConfig::default()
            },
            tiny_config(),
            |_| Ok(SimModel::math_like(11)),
        )
        .unwrap(),
    )
}

#[test]
fn full_stack_over_sockets_with_batching() {
    let coord = sim_coordinator(1);
    let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
    let addr = server.addr;
    let mut handles = vec![];
    for c in 0..8 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            let r = client
                .generate("synth-math", &format!("Q: {c}+1=?"), "static:0.85")
                .unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.steps > 0);
            r.steps
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(coord.metrics.counter_value("requests_completed"), 8);
    // with 8 concurrent requests and a 1-worker continuous batcher, steps
    // must have been shared (peak occupancy > 1)
    let peak = coord.metrics.gauge("batch_occupancy_peak");
    assert!(
        peak.load(std::sync::atomic::Ordering::Relaxed) >= 2,
        "continuous batching never formed a batch"
    );
    server.stop();
}

#[test]
fn osdt_calibration_shared_across_connections() {
    let coord = sim_coordinator(2);
    let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
    let addr = server.addr;
    let spec = "osdt:step-block:q2:0.75:0.2";
    let mut c1 = Client::connect(addr).unwrap();
    let r1 = c1.generate("synth-math", "Q: 1+1=?", spec).unwrap();
    assert!(r1.calibrated);
    // second connection, same task: must reuse the shared profile
    let mut c2 = Client::connect(addr).unwrap();
    let r2 = c2.generate("synth-math", "Q: 2+2=?", spec).unwrap();
    assert!(!r2.calibrated);
    assert_eq!(coord.metrics.counter_value("calibrations"), 1);
    server.stop();
}

#[test]
fn mixed_policies_in_one_batch() {
    let coord = sim_coordinator(1);
    let mut rxs = vec![];
    for (i, pol) in ["static:0.9", "sequential:1", "factor:0.95", "static:0.7"]
        .iter()
        .enumerate()
    {
        rxs.push((
            *pol,
            coord.submit(Request {
                id: 0,
                task: "synth-math".into(),
                prompt: format!("Q: {i}+3=?"),
                policy: pol.to_string(),
                slo_ms: None,
            }),
        ));
    }
    let cfg = tiny_config();
    for (pol, rx) in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{pol}: {:?}", r.error);
        if pol == "sequential:1" {
            assert_eq!(r.steps, cfg.gen_len, "sequential quota is exact");
        } else {
            assert!(r.steps < cfg.gen_len, "{pol} must parallelise");
        }
    }
}

#[test]
fn profile_store_roundtrip_through_decode() {
    // calibrate -> persist -> reload -> decode: the offline workflow
    let m = SimModel::qa_like(3);
    let engine = Engine::new(&m);
    let cal = engine
        .decode(m.layout_from_seed(0), &StaticThreshold::new(0.9))
        .unwrap();
    let profile = Calibrator::calibrate(&cal.trace, DynamicMode::StepBlock, Metric::Q1);
    let dir = std::env::temp_dir().join(format!("osdt_it_{}", std::process::id()));
    let store = ProfileStore::new(&dir).unwrap();
    store
        .save(&ProfileRecord::new(
            "synth-qa",
            profile.clone(),
            cal.trace.signature(),
        ))
        .unwrap();
    let loaded = store
        .load("synth-qa", DynamicMode::StepBlock, Metric::Q1)
        .unwrap();
    assert_eq!(profile, loaded.profile);
    assert_eq!(loaded.signature, cal.trace.signature());
    let osdt = Osdt::from_profile(loaded.profile, 0.75, 0.2);
    let res = engine.decode(m.layout_from_seed(5), &osdt).unwrap();
    assert!(res.steps >= tiny_config().num_blocks);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn prop_decode_invariants_across_policies_and_tasks() {
    // for random policies/tasks/seeds: decode terminates, fills the gen
    // region, never exceeds gen_len steps, takes at least num_blocks steps,
    // and trace length == steps
    prop::forall(
        "decode-invariants",
        60,
        |r: &mut Rng| {
            let task = r.below(3);
            let policy = r.below(4);
            let tau = 0.3 + r.next_f64() * 0.69;
            let seed = r.next_u64();
            (task, policy, tau, seed)
        },
        |&(task, policy, tau, seed)| {
            let m = match task {
                0 => SimModel::math_like(seed),
                1 => SimModel::qa_like(seed),
                _ => SimModel::code_like(seed),
            };
            let engine = Engine::new(&m);
            let p: Box<dyn osdt::policy::Policy> = match policy {
                0 => Box::new(SequentialTopK::new(1 + (seed % 4) as usize)),
                1 => Box::new(StaticThreshold::new(tau)),
                2 => Box::new(osdt::policy::FactorThreshold::new(tau)),
                _ => {
                    let cal = engine
                        .decode(m.layout_from_seed(0), &StaticThreshold::new(0.9))
                        .map_err(|e| e.to_string())?;
                    let prof = Calibrator::calibrate(
                        &cal.trace,
                        DynamicMode::Block,
                        Metric::Q1,
                    );
                    Box::new(Osdt::from_profile(prof, tau, 0.1))
                }
            };
            let cfg = m.config().clone();
            let res = engine
                .decode(m.layout_from_seed(seed ^ 0xAB), p.as_ref())
                .map_err(|e| e.to_string())?;
            if res.gen_tokens(&cfg).iter().any(|&t| t == cfg.mask_id) {
                return Err("masks remain".into());
            }
            if res.steps > cfg.gen_len {
                return Err(format!("steps {} > gen_len", res.steps));
            }
            if res.steps < cfg.num_blocks {
                return Err(format!("steps {} < num_blocks", res.steps));
            }
            if res.trace.total_steps() != res.steps {
                return Err("trace/steps mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cached_equals_uncached_on_simulator() {
    // the simulator's window path is exact, so the dual-cache decode must
    // match the plain decode bit-for-bit across random settings
    prop::forall(
        "cache-exactness",
        40,
        |r: &mut Rng| (r.next_u64(), 0.4 + r.next_f64() * 0.55),
        |&(seed, tau)| {
            let m = SimModel::math_like(seed);
            let plain = Engine::new(&m);
            let cached = Engine::with_kv_cache(&m);
            let p = StaticThreshold::new(tau);
            let a = plain
                .decode(m.layout_from_seed(seed), &p)
                .map_err(|e| e.to_string())?;
            let b = cached
                .decode(m.layout_from_seed(seed), &p)
                .map_err(|e| e.to_string())?;
            if a.tokens != b.tokens {
                return Err("tokens differ".into());
            }
            if a.steps != b.steps {
                return Err(format!("steps {} vs {}", a.steps, b.steps));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_osdt_throughput_monotone_in_epsilon() {
    // more slack -> laxer thresholds -> no more steps than before
    prop::forall(
        "epsilon-monotone-steps",
        30,
        |r: &mut Rng| (r.next_u64(), r.next_f64() * 0.3),
        |&(seed, e1)| {
            let m = SimModel::math_like(seed);
            let engine = Engine::new(&m);
            let cal = engine
                .decode(m.layout_from_seed(0), &StaticThreshold::new(0.9))
                .map_err(|e| e.to_string())?;
            let prof =
                Calibrator::calibrate(&cal.trace, DynamicMode::Block, Metric::Median);
            let e2 = e1 + 0.3;
            let a = engine
                .decode(
                    m.layout_from_seed(9),
                    &Osdt::from_profile(prof.clone(), 1.0, e1),
                )
                .map_err(|e| e.to_string())?;
            let b = engine
                .decode(
                    m.layout_from_seed(9),
                    &Osdt::from_profile(prof, 1.0, e2),
                )
                .map_err(|e| e.to_string())?;
            if b.steps > a.steps {
                return Err(format!("eps {e2} took {} > {} steps", b.steps, a.steps));
            }
            Ok(())
        },
    );
}
