//! Integration tests for bucketed window dispatch and the paged KV pool
//! with prompt-prefix sharing (DESIGN.md §13) over the analytic simulator:
//! padded-bucket groups must be invisible in per-sequence results, window
//! groups must co-execute past the legacy max-batch ceiling, and prefix
//! sharing must skip real `fwd_full_kv` executions without changing a
//! single token.

use osdt::cache::CacheConfig;
use osdt::decode::{DecodeResult, Engine, ForwardModel, StepScheduler};
use osdt::model::fixtures::tiny_config;
use osdt::policy::{FactorThreshold, Policy, SequentialTopK, StaticThreshold};
use osdt::sim::SimModel;
use osdt::util::prop;
use osdt::util::rng::Rng;

/// Single-block simulator: one K/V refresh per decode, so executed
/// refreshes are directly comparable to request counts.
fn one_block_model(seed: u64) -> SimModel {
    let mut cfg = tiny_config();
    cfg.gen_len = cfg.block_len;
    cfg.num_blocks = 1;
    cfg.seq_len = cfg.prompt_len + cfg.gen_len;
    SimModel::math_like(seed).with_config(cfg)
}

#[test]
fn sim_model_advertises_the_compiled_bucket_ladder() {
    assert_eq!(SimModel::math_like(1).window_buckets(), vec![1, 2, 4, 8, 16, 32]);
}

#[test]
fn padded_bucket_groups_match_solo_across_sizes() {
    // sizes straddling every bucket boundary: exact fits and padded
    // remainders both dispatch token-identically to solo decode
    let m = SimModel::math_like(31);
    let p = StaticThreshold::new(0.85);
    let eng = Engine::with_cache(&m, CacheConfig::block_boundary());
    for n in [1usize, 2, 3, 5, 8, 9, 16, 17, 31, 32] {
        let layouts: Vec<Vec<u32>> =
            (0..n).map(|i| m.layout_from_seed(100 + i as u64)).collect();
        let solos: Vec<DecodeResult> = layouts
            .iter()
            .map(|l| eng.decode(l.clone(), &p).unwrap())
            .collect();
        let refs: Vec<&dyn Policy> = (0..n).map(|_| &p as &dyn Policy).collect();
        let batched = eng.decode_batch(layouts, &refs).unwrap();
        for (i, (b, s)) in batched.iter().zip(&solos).enumerate() {
            assert_eq!(b.tokens, s.tokens, "group {n} seq {i}: tokens");
            assert_eq!(b.steps, s.steps, "group {n} seq {i}: steps");
        }
    }
}

#[test]
fn prop_padded_buckets_match_solo_across_policies() {
    // random group sizes 1..=32 with a mixed policy batch: bucket padding
    // is invisible in every per-sequence result
    prop::forall(
        "bucketed-transparency",
        15,
        |r: &mut Rng| (r.next_u64(), 1 + r.below(32) as usize),
        |&(seed, n)| {
            let m = SimModel::qa_like(seed);
            let eng = Engine::with_cache(&m, CacheConfig::block_boundary());
            let policies: Vec<Box<dyn Policy>> = (0..n)
                .map(|i| match i % 3 {
                    0 => Box::new(StaticThreshold::new(0.8)) as Box<dyn Policy>,
                    1 => Box::new(FactorThreshold::new(0.93)) as Box<dyn Policy>,
                    _ => Box::new(SequentialTopK::new(2)) as Box<dyn Policy>,
                })
                .collect();
            let layouts: Vec<Vec<u32>> =
                (0..n).map(|i| m.layout_from_seed(seed ^ (i as u64))).collect();
            let solos = layouts
                .iter()
                .zip(&policies)
                .map(|(l, p)| eng.decode(l.clone(), p.as_ref()))
                .collect::<Result<Vec<_>, _>>()
                .map_err(|e| e.to_string())?;
            let refs: Vec<&dyn Policy> =
                policies.iter().map(|p| p.as_ref()).collect();
            let batched = eng
                .decode_batch(layouts, &refs)
                .map_err(|e| e.to_string())?;
            for (i, (b, s)) in batched.iter().zip(&solos).enumerate() {
                if b.tokens != s.tokens {
                    return Err(format!("size {n} seq {i}: tokens differ"));
                }
                if b.steps != s.steps {
                    return Err(format!("size {n} seq {i}: steps differ"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn window_groups_co_execute_past_legacy_max_batch() {
    // 9 fusible rows: one fused window group at bucket 16 — not three
    // max_batch-sized fragments — with the 7 padding rows reported
    let m = SimModel::math_like(33);
    assert_eq!(m.max_batch(), 4, "test assumes the legacy ceiling is 4");
    let p = StaticThreshold::new(0.85);
    let mut sched: StepScheduler<'_, SimModel, &dyn Policy> =
        StepScheduler::new(&m, CacheConfig::block_boundary(), 9);
    for i in 0..9u64 {
        sched.admit(i, m.layout_from_seed(200 + i), &p).unwrap();
    }
    let r1 = sched.step().unwrap();
    assert_eq!(r1.occupancy, 9, "all rows admitted past max_batch");
    assert_eq!(r1.full_passes, 9, "block-0 refreshes");
    let r2 = sched.step().unwrap();
    assert_eq!(r2.occupancy, 9);
    assert!(
        r2.fused_window_passes >= 9,
        "9 rows must share the fused path, got {}",
        r2.fused_window_passes
    );
    assert!(
        r2.window_groups.iter().any(|&(live, bucket)| live == 9 && bucket == 16),
        "expected a (9, 16) window group, got {:?}",
        r2.window_groups
    );
    assert_eq!(r2.padding_rows, 16 - 9);
}

#[test]
fn padding_rows_never_skew_live_metrics() {
    // 5 live rows pad to bucket 8: occupancy and per-row acceptance see
    // exactly the live rows, only the padding counters see the rest
    let m = SimModel::math_like(34);
    let p = StaticThreshold::new(0.85);
    let mut sched: StepScheduler<'_, SimModel, &dyn Policy> =
        StepScheduler::new(&m, CacheConfig::block_boundary(), 5);
    for i in 0..5u64 {
        sched.admit(i, m.layout_from_seed(300 + i), &p).unwrap();
    }
    sched.step().unwrap(); // block-0 refreshes
    let r = sched.step().unwrap(); // first window step
    assert_eq!(r.occupancy, 5, "occupancy counts live rows only");
    assert_eq!(r.window_groups, vec![(5, 8)]);
    assert_eq!(r.padding_rows, 3);
    assert!(
        r.accepted.len() <= 5 && r.accepted.iter().all(|&(id, _)| id < 5),
        "accepted rows must all be live sequences: {:?}",
        r.accepted
    );
}

#[test]
fn prefix_sharing_skips_refreshes_and_keeps_tokens() {
    // 6 requests over 2 prompt templates on a single-block config: the
    // sharing run must execute exactly one refresh per template — strictly
    // fewer than requests — and match the unshared run token for token
    let m = one_block_model(7);
    let n = 6usize;
    let templates = 2u64;
    let p = StaticThreshold::new(0.85);
    let layouts: Vec<Vec<u32>> =
        (0..n).map(|i| m.layout_from_seed(i as u64 % templates)).collect();
    let refs: Vec<&dyn Policy> = (0..n).map(|_| &p as &dyn Policy).collect();

    let unshared_eng = Engine::with_cache(&m, CacheConfig::block_boundary());
    let solos: Vec<DecodeResult> = layouts
        .iter()
        .map(|l| unshared_eng.decode(l.clone(), &p).unwrap())
        .collect();
    let unshared = unshared_eng.decode_batch(layouts.clone(), &refs).unwrap();

    let shared_eng = Engine::with_cache(
        &m,
        CacheConfig::block_boundary().paged(8).with_prefix_sharing(true),
    );
    let calls0 = m.full_kv_calls();
    let shared = shared_eng.decode_batch(layouts, &refs).unwrap();
    let executed = m.full_kv_calls() - calls0;

    assert!(
        executed < n as u64,
        "sharing must execute fewer refreshes ({executed}) than requests ({n})"
    );
    assert_eq!(executed, templates, "one executed refresh per template");
    for (i, ((sh, un), solo)) in
        shared.iter().zip(&unshared).zip(&solos).enumerate()
    {
        assert_eq!(sh.tokens, un.tokens, "seq {i}: shared vs unshared tokens");
        assert_eq!(sh.tokens, solo.tokens, "seq {i}: shared vs solo tokens");
        assert_eq!(sh.steps, un.steps, "seq {i}: steps");
        assert_eq!(
            sh.full_passes, un.full_passes,
            "seq {i}: hits attribute the pass, counters stay identical"
        );
    }

    let stats = shared_eng.shared_kv().expect("sharing is active").stats();
    assert!(
        stats.hits >= (n as u64) - templates,
        "expected at least {} prefix hits, got {}",
        n as u64 - templates,
        stats.hits
    );
    assert_eq!(stats.entries, templates as usize);
    // retired sequences released their tables; only the index pins pages
    let pages_per_seq = m.config().seq_len.div_ceil(8);
    assert_eq!(stats.pool.pages_in_use, templates as usize * pages_per_seq);
}

#[test]
fn prefix_sharing_composes_with_bucketed_groups() {
    // 12 same-prompt requests: one executed refresh, then all 12 co-execute
    // window steps in a bucket-16 group — the two tentpole halves together
    let m = one_block_model(11);
    let p = StaticThreshold::new(0.85);
    let mut sched: StepScheduler<'_, SimModel, &dyn Policy> = Engine::with_cache(
        &m,
        CacheConfig::block_boundary().paged(8).with_prefix_sharing(true),
    )
    .scheduler(12);
    let calls0 = m.full_kv_calls();
    for i in 0..12u64 {
        sched.admit(i, m.layout_from_seed(0), &p).unwrap();
    }
    let r1 = sched.step().unwrap();
    assert_eq!(m.full_kv_calls() - calls0, 1, "one executed refresh for 12 rows");
    assert_eq!(r1.full_passes, 12, "every row still accounts a refresh");
    assert_eq!(r1.saved_full_passes, 11);
    assert!(r1.pages_reused > 0);
    assert!(r1.kv_pages_in_use > 0);
    let r2 = sched.step().unwrap();
    assert!(
        r2.window_groups.iter().any(|&(live, bucket)| live == 12 && bucket == 16),
        "expected a (12, 16) window group, got {:?}",
        r2.window_groups
    );
    let results = sched.drain().unwrap();
    assert_eq!(results.len(), 12);
    let first = &results[0].1;
    for (id, res) in &results {
        assert_eq!(res.tokens, first.tokens, "seq {id}: identical prompts, identical tokens");
    }
}
