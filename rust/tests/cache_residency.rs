//! Cache-handle contract tests (DESIGN.md §10) on the analytic simulator —
//! no artifacts required, so the handle lifecycle (mint → install → window
//! consumption → drop → pool recycle) is exercised in every build:
//!
//! - `SimModel` conformance: the handle-based `ForwardModel` contract
//!   composes with the scheduler exactly like the old host-vector one —
//!   cached solo, cached batched, and mid-flight admission all stay
//!   token-identical;
//! - pool reuse after retirement: storage recycled from retired sequences
//!   must never leak state into later decodes (no stale rows);
//! - handle drop semantics: block rollovers and retirement return storage
//!   to the pool, bounded by its capacity.

use osdt::cache::{CacheConfig, CachePool, KvCache, Residency};
use osdt::decode::{DecodeTask, Engine, ForwardModel, PassKind};
use osdt::policy::{Policy, StaticThreshold};
use osdt::sim::SimModel;

#[test]
fn sim_mints_pooled_host_handles() {
    let m = SimModel::math_like(4);
    let cfg = m.config().clone();
    let mut task = DecodeTask::new(
        m.layout_from_seed(1),
        &cfg,
        CacheConfig::block_boundary(),
    )
    .unwrap();
    assert_eq!(task.needs(&cfg), PassKind::FullKv);
    let (out, handle) = m.fwd_full_kv(task.tokens()).unwrap();
    assert_eq!(handle.residency(), Residency::Host);
    assert_eq!(
        handle.dims(),
        [cfg.n_layers, cfg.n_heads, cfg.seq_len, cfg.head_dim]
    );
    task.install_cache(handle);
    assert!(task.cache().is_some());
    let p = StaticThreshold::new(0.9);
    task.apply(&cfg, &p, PassKind::FullKv, out.conf_row(0), out.argmax_row(0));
    assert_eq!(m.pool().stats().minted_host, 1);
}

#[test]
fn retirement_recycles_handles_into_the_pool() {
    let m = SimModel::math_like(9);
    let eng = Engine::with_kv_cache(&m);
    let p = StaticThreshold::new(0.9);
    let res = eng.decode(m.layout_from_seed(3), &p).unwrap();
    assert!(res.full_passes > 0);
    let s = m.pool().stats();
    // one handle minted per FullKv refresh; every one of them was dropped
    // (block rollover or retirement) and came back to the pool
    assert_eq!(s.minted_host, res.full_passes as u64);
    assert_eq!(
        s.reclaimed_host + s.dropped,
        s.minted_host,
        "all handles must be reclaimed once the sequence retires: {s:?}"
    );
    let (host_free, _) = m.pool().free_len();
    assert!(host_free > 0);
}

#[test]
fn pool_reuse_after_retirement_has_no_stale_rows() {
    // decode several sequences back-to-back on one model (storage recycled
    // across them) and compare against decodes on fresh models (storage
    // never recycled): token-identical or the pool leaked state
    let p = StaticThreshold::new(0.88);
    let shared = SimModel::math_like(11);
    let eng = Engine::with_kv_cache(&shared);
    let mut recycled = Vec::new();
    for seed in 0..5 {
        recycled.push(eng.decode(shared.layout_from_seed(seed), &p).unwrap());
    }
    assert!(
        shared.pool().stats().reused_host > 0,
        "back-to-back decodes must actually reuse pooled storage: {:?}",
        shared.pool().stats()
    );
    for (seed, got) in recycled.iter().enumerate() {
        let fresh_model = SimModel::math_like(11);
        let fresh_eng = Engine::with_kv_cache(&fresh_model);
        let want = fresh_eng
            .decode(fresh_model.layout_from_seed(seed as u64), &p)
            .unwrap();
        assert_eq!(got.tokens, want.tokens, "stale pool rows at seed {seed}");
        assert_eq!(got.steps, want.steps);
    }
}

#[test]
fn cached_batched_decode_conforms_through_handles() {
    // the scheduler groups window passes by handle — batched cached decode
    // must equal solo cached decode under the handle contract
    let m = SimModel::qa_like(6);
    let eng = Engine::with_kv_cache(&m);
    let p = StaticThreshold::new(0.9);
    let layouts: Vec<Vec<u32>> = (0..4).map(|i| m.layout_from_seed(40 + i)).collect();
    let solos: Vec<_> = layouts
        .iter()
        .map(|l| eng.decode(l.clone(), &p).unwrap())
        .collect();
    let policies: Vec<&dyn Policy> = layouts.iter().map(|_| &p as &dyn Policy).collect();
    let batched = eng.decode_batch(layouts, &policies).unwrap();
    for (b, s) in batched.iter().zip(&solos) {
        assert_eq!(b.tokens, s.tokens);
        assert_eq!(b.steps, s.steps);
        assert_eq!(b.window_passes, s.window_passes);
    }
    // every minted handle from all decodes was returned on retirement
    let st = m.pool().stats();
    assert_eq!(st.reclaimed_host + st.dropped, st.minted_host);
}

#[test]
fn unpooled_handles_and_mixed_batches_hit_the_fallback() {
    // a hand-built host handle (no pool) must work through fwd_window_batch
    let m = SimModel::math_like(2);
    let cfg = m.config().clone();
    let dims = [cfg.n_layers, cfg.n_heads, cfg.seq_len, cfg.head_dim];
    let n: usize = dims.iter().product();
    let handle = osdt::cache::CacheHandle::host(KvCache {
        k: vec![0.0; n],
        v: vec![0.0; n],
        dims,
    });
    let layout = m.layout_from_seed(0);
    let window = &layout[cfg.block_range(0)];
    let start = cfg.block_range(0).start;
    let solo = m.fwd_window(window, start, &handle).unwrap();
    let batch = m
        .fwd_window_batch(&[window, window], &[start, start], &[&handle, &handle])
        .unwrap();
    assert_eq!(batch.len(), 2);
    assert_eq!(batch.conf_row(0), solo.conf_row(0));
    assert_eq!(batch.argmax_row(1), solo.argmax_row(0));
}

#[test]
fn pool_capacity_is_respected_under_load() {
    let pool = CachePool::new([1, 1, 4, 1], 2);
    let handles: Vec<_> = (0..5)
        .map(|_| {
            let mut kv = pool.take_host_storage();
            kv.k.resize(4, 1.0);
            kv.v.resize(4, 1.0);
            pool.wrap_host(kv)
        })
        .collect();
    drop(handles);
    let (host_free, _) = pool.free_len();
    assert_eq!(host_free, 2, "free list must be capacity-bounded");
    let s = pool.stats();
    assert_eq!(s.reclaimed_host, 2);
    assert_eq!(s.dropped, 3);
}
