//! Process-level chaos: the fleet tier (supervisor + router + replica
//! *processes*) under real SIGKILLs, crashed supervisors, and rolling
//! restarts (DESIGN.md §16).
//!
//! Unlike `tests/chaos.rs` (in-process fault injection through
//! `sim::Chaos`), every scenario here spawns the actual `osdt` binary
//! (`CARGO_BIN_EXE_osdt`) and kills real PIDs. The invariants:
//!
//! 1. a SIGKILLed replica is detected within heartbeats, in-flight and
//!    subsequent requests fail over with token-identical completions,
//!    and the slot respawns on its original port;
//! 2. `--chaos-die-after` aborts a replica *mid-decode* (no unwinding,
//!    no reply) and the router retries on the survivor without token
//!    corruption;
//! 3. a stale `state.json` (dead supervisor PID) is detected on the
//!    next start and still-live replicas are adopted, not restarted;
//! 4. a rolling restart under sustained load drops zero requests and
//!    triggers zero fleet-wide recalibrations;
//! 5. a replica dying mid-rolling-restart is still respawned — the
//!    fleet converges to fully healthy.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use osdt::fleet::state::free_port;
use osdt::fleet::{
    probe_ping, roundtrip_line, FleetConfig, FleetRouter, FleetState,
    ReplicaSpec, ReplicaState, RouterConfig, Supervisor,
};
use osdt::policy::ProfileStore;
use osdt::server::{Client, RetryPolicy};
use osdt::util::json::Json;
use osdt::util::procfs::{pid_alive, send_signal};

const OSDT_SPEC: &str = "osdt:block:q1:0.75:0.2";

fn binary() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_osdt"))
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("osdt-fleet-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Fast-heartbeat fleet config for tests: deaths are detected in
/// ~150ms and respawns retry within half a second.
fn fleet_cfg(tag: &str, replicas: usize) -> FleetConfig {
    FleetConfig {
        dir: tmpdir(tag),
        binary: binary(),
        replicas,
        heartbeat: Duration::from_millis(150),
        respawn_base: Duration::from_millis(50),
        respawn_max: Duration::from_millis(400),
        request_timeout: Duration::from_secs(10),
        ..FleetConfig::default()
    }
}

/// Generous client-side retry budget: requests during an outage window
/// must eventually land (shed responses carry finite hints and are
/// retried; transport drops reconnect).
fn retry() -> RetryPolicy {
    RetryPolicy {
        max_retries: 10,
        backoff_base: Duration::from_millis(25),
        backoff_max: Duration::from_millis(250),
        seed: 7,
    }
}

/// Spawn a bare single-process replica (`serve --backend=sim`).
fn spawn_serve(addr: &str, extra: &[&str]) -> Child {
    let mut cmd = Command::new(binary());
    cmd.arg("serve")
        .arg(format!("--addr={addr}"))
        .arg("--backend=sim")
        .arg("--sim-seed=5")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null());
    for a in extra {
        cmd.arg(a);
    }
    cmd.spawn().unwrap()
}

fn wait_ping(addr: &str, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    while !probe_ping(addr, Duration::from_millis(250)) {
        assert!(Instant::now() < deadline, "{addr} never served pings");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Parse one counter out of a rendered Prometheus text blob.
fn counter_in(render: &str, family: &str) -> u64 {
    let prefix = format!("osdt_{family}_total ");
    render
        .lines()
        .find_map(|l| l.strip_prefix(&prefix))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

#[test]
fn sigkilled_replica_fails_over_and_respawns_on_its_port() {
    let cfg = fleet_cfg("sigkill", 2);
    let dir = cfg.dir.clone();
    let heartbeat = cfg.heartbeat;
    let sup = Supervisor::start(cfg).unwrap();
    assert!(
        sup.wait_all_healthy(Duration::from_secs(30)),
        "fleet never became healthy"
    );

    let mut c = Client::connect(sup.router_addr.as_str()).unwrap();
    let retry = retry();
    let baseline = c
        .generate_with_retry("synth-math", "Q: 2+3=?", "static:0.9", &retry)
        .unwrap();
    assert!(baseline.error.is_none(), "{:?}", baseline.error);

    // SIGKILL replica 0 (the real process, per state.json).
    let st = FleetState::load(&dir).unwrap().unwrap();
    let victim = st.replicas.iter().find(|r| r.id == 0).unwrap().clone();
    assert!(pid_alive(victim.pid));
    assert!(send_signal(victim.pid, "KILL"));

    // Every request during the outage is either served by the survivor
    // or shed with a finite hint and retried by the client helper —
    // never dropped, and never token-corrupted (shared sim seed).
    for i in 0..5 {
        let r = c
            .generate_with_retry("synth-math", "Q: 2+3=?", "static:0.9", &retry)
            .unwrap();
        assert!(r.error.is_none(), "request {i}: {:?}", r.error);
        assert_eq!(
            r.completion, baseline.completion,
            "failover corrupted tokens (request {i})"
        );
    }

    // The router noticed the death (failed forward or health probe).
    std::thread::sleep(heartbeat * 2);
    let m = roundtrip_line(
        &sup.router_addr,
        r#"{"cmd":"metrics"}"#,
        Duration::from_secs(2),
    )
    .unwrap();
    let render = m.get("metrics").and_then(Json::as_str).unwrap().to_string();
    assert!(
        counter_in(&render, "fleet_replica_failures") >= 1,
        "router never marked the SIGKILLed replica unhealthy:\n{render}"
    );

    // The supervisor respawns the slot on its original port.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let st = FleetState::load(&dir).unwrap().unwrap();
        let row = st.replicas.iter().find(|r| r.id == 0).unwrap();
        if row.pid != 0
            && row.pid != victim.pid
            && pid_alive(row.pid)
            && probe_ping(&row.addr, Duration::from_millis(250))
        {
            assert_eq!(row.addr, victim.addr, "respawn must reuse the port");
            break;
        }
        assert!(Instant::now() < deadline, "replica 0 never respawned");
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(sup.metrics().counter_value("fleet_respawns") >= 1);
    sup.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chaos_abort_mid_decode_fails_over_with_identical_tokens() {
    // One replica armed to abort() on its first forward pass — a
    // SIGKILL-grade death *mid-decode* (no unwinding, no reply line) —
    // plus one healthy survivor on the same sim seed.
    let doomed_addr = format!("127.0.0.1:{}", free_port().unwrap());
    let healthy_addr = format!("127.0.0.1:{}", free_port().unwrap());
    let mut doomed = spawn_serve(&doomed_addr, &["--chaos-die-after=1"]);
    let mut healthy = spawn_serve(&healthy_addr, &[]);
    wait_ping(&doomed_addr, Duration::from_secs(30));
    wait_ping(&healthy_addr, Duration::from_secs(30));

    // Baseline straight from the survivor.
    let mut direct = Client::connect(healthy_addr.as_str()).unwrap();
    let baseline =
        direct.generate("synth-math", "Q: 7+8=?", "static:0.9").unwrap();
    assert!(baseline.error.is_none(), "{:?}", baseline.error);

    let router = FleetRouter::start(RouterConfig {
        replicas: vec![
            ReplicaSpec { id: 0, addr: doomed_addr.clone() },
            ReplicaSpec { id: 1, addr: healthy_addr.clone() },
        ],
        health_interval: Duration::from_millis(100),
        request_timeout: Duration::from_secs(10),
        max_retries: 3,
        backoff_base: Duration::from_millis(5),
        backoff_max: Duration::from_millis(40),
        ..RouterConfig::default()
    })
    .unwrap();

    // Ties go to the lowest id, so the first forward lands on the doomed
    // replica and dies mid-decode. The router must retry on the survivor
    // and hand back token-identical output.
    let mut c = Client::connect(router.addr).unwrap();
    let r = c.generate("synth-math", "Q: 7+8=?", "static:0.9").unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.completion, baseline.completion, "failover corrupted tokens");
    let m = router.metrics();
    assert!(m.counter_value("fleet_request_retries") >= 1, "no retry recorded");
    assert!(m.counter_value("fleet_replica_failures") >= 1);

    // The doomed process really died (abort, not a clean exit).
    let deadline = Instant::now() + Duration::from_secs(10);
    let status = loop {
        if let Some(s) = doomed.try_wait().unwrap() {
            break s;
        }
        if Instant::now() > deadline {
            let _ = doomed.kill();
            let _ = doomed.wait();
            panic!("armed replica survived its fatal forward pass");
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    assert!(!status.success(), "abort() must not exit cleanly");

    router.stop();
    let _ = healthy.kill();
    let _ = healthy.wait();
}

#[test]
fn stale_state_file_is_detected_and_live_replica_adopted() {
    let dir = tmpdir("stale");
    let addr = format!("127.0.0.1:{}", free_port().unwrap());
    let mut orphan = spawn_serve(&addr, &[]);
    wait_ping(&addr, Duration::from_secs(30));
    let orphan_pid = orphan.id();

    // Forge the aftermath of a crashed supervisor: state.json names a
    // dead supervisor PID but a live, still-serving replica.
    let mut st = FleetState::new("127.0.0.1:1".into());
    st.supervisor_pid = u32::MAX;
    st.replicas = vec![ReplicaState {
        id: 0,
        pid: orphan_pid,
        addr: addr.clone(),
        respawns: 3,
    }];
    st.save(&dir).unwrap();

    let mut cfg = fleet_cfg("stale-sup", 1);
    let spare = std::mem::replace(&mut cfg.dir, dir.clone());
    let _ = std::fs::remove_dir_all(&spare); // fleet_cfg's tmpdir, unused
    let sup = Supervisor::start(cfg).unwrap();
    assert_eq!(
        sup.metrics().counter_value("fleet_stale_states_recovered"),
        1,
        "stale state must be detected and counted"
    );
    assert!(sup.wait_all_healthy(Duration::from_secs(30)));

    // Adopted, not respawned: same PID, respawn history preserved.
    let now = FleetState::load(&dir).unwrap().unwrap();
    let row = now.replicas.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(row.pid, orphan_pid, "live replica must be adopted");
    assert_eq!(row.respawns, 3, "respawn count survives adoption");
    assert_eq!(sup.metrics().counter_value("fleet_respawns"), 0);

    // Serving works through the freshly spawned router.
    let mut c = Client::connect(sup.router_addr.as_str()).unwrap();
    let r = c
        .generate_with_retry("synth-math", "Q: 6+1=?", "static:0.9", &retry())
        .unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);

    // Clean shutdown kills the adopted process and removes state.json,
    // so the *next* start is Absent, not Stale.
    sup.shutdown();
    assert_eq!(FleetState::load(&dir).unwrap(), None);
    let _ = orphan.wait(); // reap the SIGKILLed child
    assert!(!pid_alive(orphan_pid));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rolling_restart_under_load_drops_nothing_and_recalibrates_nothing() {
    let cfg = fleet_cfg("rolling", 2);
    let dir = cfg.dir.clone();
    let sup = Supervisor::start(cfg).unwrap();
    assert!(sup.wait_all_healthy(Duration::from_secs(30)));
    let router_addr = sup.router_addr.clone();

    // Warm the shared profile once: the first OSDT request calibrates
    // and bumps the fleet-wide store generation.
    let mut c = Client::connect(router_addr.as_str()).unwrap();
    let warm = c
        .generate_with_retry("synth-math", "Q: 1+2=?", OSDT_SPEC, &retry())
        .unwrap();
    assert!(warm.error.is_none(), "{:?}", warm.error);
    let store = ProfileStore::new(dir.join("profiles")).unwrap();
    let gen_before = store.generation();
    assert!(gen_before >= 1, "calibration must bump the store generation");

    let st = FleetState::load(&dir).unwrap().unwrap();
    let mut pids_before: Vec<(usize, u32)> =
        st.replicas.iter().map(|r| (r.id, r.pid)).collect();
    pids_before.sort_unstable();

    // Sustained load from a second connection while the fleet rolls.
    let stop = Arc::new(AtomicBool::new(false));
    let load = {
        let stop = stop.clone();
        let addr = router_addr.clone();
        std::thread::spawn(move || -> (u64, Vec<String>) {
            let mut c = Client::connect(addr.as_str()).unwrap();
            let retry = retry();
            let mut ok = 0u64;
            let mut failures = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                match c.generate_with_retry(
                    "synth-math",
                    "Q: 4+5=?",
                    OSDT_SPEC,
                    &retry,
                ) {
                    Ok(r) if r.error.is_none() => ok += 1,
                    Ok(r) => failures.push(format!("{:?}", r.error)),
                    Err(e) => failures.push(format!("{e:#}")),
                }
            }
            (ok, failures)
        })
    };
    std::thread::sleep(Duration::from_millis(200));

    let restarted = sup.rolling_restart().unwrap();
    assert_eq!(restarted, 2);
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Ordering::Relaxed);
    let (completed, failures) = load.join().unwrap();
    assert!(failures.is_empty(), "dropped requests: {failures:?}");
    assert!(completed > 0, "load thread never completed a request");

    // Every replica is a new process on its old port...
    let st = FleetState::load(&dir).unwrap().unwrap();
    for r in &st.replicas {
        let old = pids_before.iter().find(|(id, _)| *id == r.id).unwrap().1;
        assert_ne!(r.pid, old, "replica {} was not restarted", r.id);
        assert!(pid_alive(r.pid));
    }
    // ...and the restart caused zero fleet-wide recalibrations: the new
    // processes adopt the stored profile instead of re-deriving it.
    assert_eq!(
        store.generation(),
        gen_before,
        "rolling restart must not recalibrate"
    );
    assert_eq!(sup.metrics().counter_value("fleet_rolling_restarts"), 1);
    assert!(sup.metrics().counter_value("fleet_respawns") >= 2);
    sup.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replica_death_mid_rolling_restart_still_converges_healthy() {
    let cfg = fleet_cfg("mid-roll", 2);
    let dir = cfg.dir.clone();
    let sup = Supervisor::start(cfg).unwrap();
    assert!(sup.wait_all_healthy(Duration::from_secs(30)));

    let st = FleetState::load(&dir).unwrap().unwrap();
    let bystander = st.replicas.iter().find(|r| r.id == 1).unwrap().clone();

    // Rolling restart walks replicas in id order (0 first). Kill the
    // *other* replica while the restart is busy with replica 0: the
    // heartbeat skips only the slot under restart, so the bystander's
    // death must still be noticed and respawned.
    std::thread::scope(|s| {
        let rolling = s.spawn(|| sup.rolling_restart());
        std::thread::sleep(Duration::from_millis(50));
        assert!(send_signal(bystander.pid, "KILL"));
        let result = rolling.join().unwrap();
        assert!(result.is_ok(), "rolling restart failed: {result:?}");
    });

    // Converges: both replicas alive, serving, on their original ports,
    // and the bystander runs a new PID.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let st = FleetState::load(&dir).unwrap().unwrap();
        let all_up = st.replicas.len() == 2
            && st.replicas.iter().all(|r| {
                r.pid != 0
                    && pid_alive(r.pid)
                    && probe_ping(&r.addr, Duration::from_millis(250))
            });
        if all_up {
            let row = st.replicas.iter().find(|r| r.id == 1).unwrap();
            assert_eq!(row.addr, bystander.addr);
            assert_ne!(row.pid, bystander.pid);
            break;
        }
        assert!(Instant::now() < deadline, "fleet never converged: {st:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
    let mut c = Client::connect(sup.router_addr.as_str()).unwrap();
    let r = c
        .generate_with_retry("synth-math", "Q: 9+9=?", "static:0.9", &retry())
        .unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    // Two rolling respawns plus the bystander's heartbeat respawn (the
    // exact count depends on interleaving; at least the two rolls).
    assert!(sup.metrics().counter_value("fleet_respawns") >= 2);
    sup.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
