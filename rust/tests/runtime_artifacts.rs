//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These exercise the full L1+L2+L3 composition: HLO text emitted by
//! python (containing the Pallas kernels) loaded, compiled and executed
//! from Rust, cross-validated against a golden vector computed by JAX
//! (`artifacts/golden_fwd.json`, written at build time).
//!
//! All tests skip gracefully when artifacts are absent (pre-`make
//! artifacts` builds).

use osdt::decode::Engine;
use osdt::model::ModelConfig;
use osdt::policy::{SequentialTopK, StaticThreshold};
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("model_config.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn load() -> (ModelConfig, ModelRuntime, Tokenizer) {
    let dir = artifacts_dir().unwrap();
    let cfg = ModelConfig::load(&dir).unwrap();
    let rt = ModelRuntime::load(&cfg).unwrap();
    let tok = Tokenizer::from_config(&cfg).unwrap();
    (cfg, rt, tok)
}

#[test]
fn fwd_conf_matches_python_golden() {
    let dir = require_artifacts!();
    let golden_path = dir.join("golden_fwd.json");
    if !golden_path.exists() {
        eprintln!("skipping: golden_fwd.json not present");
        return;
    }
    let gold = Json::parse(&std::fs::read_to_string(golden_path).unwrap()).unwrap();
    let prompt = gold.get("prompt").unwrap().as_str().unwrap();
    let want_conf: Vec<f64> = gold
        .get("conf_64_72")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    let want_arg: Vec<u32> = gold
        .get("argmax_64_72")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u32().unwrap())
        .collect();

    let (cfg, rt, tok) = load();
    let layout = tok.layout_prompt(&cfg, prompt).unwrap();
    let out = rt.fwd_conf(&[layout.as_slice()]).unwrap();
    for i in 0..8 {
        let got = f64::from(out.conf[0][64 + i]);
        assert!(
            (got - want_conf[i]).abs() < 1e-4,
            "conf[{i}]: rust {got} vs jax {}",
            want_conf[i]
        );
        assert_eq!(out.argmax[0][64 + i], want_arg[i], "argmax[{i}]");
    }
}

#[test]
fn batch_variants_agree_with_b1() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let l1 = tok.layout_prompt(&cfg, "Q: 5+6=?").unwrap();
    let l2 = tok.layout_prompt(&cfg, "Q: 9-2=?").unwrap();
    let solo1 = rt.fwd_conf(&[l1.as_slice()]).unwrap();
    let solo2 = rt.fwd_conf(&[l2.as_slice()]).unwrap();
    let both = rt.fwd_conf(&[l1.as_slice(), l2.as_slice()]).unwrap(); // compiled b2 variant
    for (a, b) in [(&solo1.conf[0], &both.conf[0]), (&solo2.conf[0], &both.conf[1])] {
        for i in 0..cfg.seq_len {
            assert!(
                (a[i] - b[i]).abs() < 1e-5,
                "batched conf differs at {i}: {} vs {}",
                a[i],
                b[i]
            );
        }
    }
    assert_eq!(solo1.argmax[0], both.argmax[0]);
    assert_eq!(solo2.argmax[0], both.argmax[1]);
}

#[test]
fn full_kv_conf_matches_fwd_conf() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let layout = tok.layout_prompt(&cfg, "Q: class of foo?").unwrap();
    let plain = rt.fwd_conf(&[layout.as_slice()]).unwrap();
    let (kvout, cache) = rt.fwd_full_kv(&layout).unwrap();
    for i in 0..cfg.seq_len {
        assert!(
            (plain.conf[0][i] - kvout.conf[0][i]).abs() < 1e-5,
            "conf differs at {i}"
        );
    }
    assert_eq!(plain.argmax[0], kvout.argmax[0]);
    let want: usize = cache.dims.iter().product();
    assert_eq!(cache.k.len(), want);
    assert!(cache.k.iter().all(|x| x.is_finite()));
}

#[test]
fn window_matches_full_on_fresh_cache() {
    // Fast-dLLM DualCache exactness at step 0 of a block, on the real model
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let layout = tok.layout_prompt(&cfg, "op: rev | in: abcd").unwrap();
    let (full, cache) = rt.fwd_full_kv(&layout).unwrap();
    for b in 0..cfg.num_blocks {
        let range = cfg.block_range(b);
        let window: Vec<u32> = layout[range.clone()].to_vec();
        let out = rt.fwd_window(&window, range.start, &cache).unwrap();
        for (i, pos) in range.clone().enumerate() {
            assert!(
                (out.conf[0][i] - full.conf[0][pos]).abs() < 1e-4,
                "block {b} pos {pos}: window {} vs full {}",
                out.conf[0][i],
                full.conf[0][pos]
            );
            assert_eq!(out.argmax[0][i], full.argmax[0][pos], "block {b} pos {pos}");
        }
    }
}

#[test]
fn decode_fills_gen_region_real_model() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let engine = Engine::new(&rt);
    let layout = tok.layout_prompt(&cfg, "Q: 3+4=?").unwrap();
    let res = engine.decode(layout, &StaticThreshold::new(0.9)).unwrap();
    let gen = res.gen_tokens(&cfg);
    assert!(gen.iter().all(|&t| t != cfg.mask_id), "masks remain");
    assert!(res.steps >= cfg.num_blocks);
    assert!(res.steps <= cfg.gen_len);
    let text = tok.decode_until_eos(gen);
    // trained model should answer the sum with its worked-steps format
    eprintln!("decoded: {text}");
    assert!(text.contains("A:"), "unexpected decode: {text}");
}

#[test]
fn cached_decode_close_to_uncached_real_model() {
    // The dual cache is an approximation on a real model (stale prefix /
    // suffix K/V within a block) — but with static τ=0.9 both paths must
    // produce valid completions and comparable step counts.
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let plain = Engine::new(&rt);
    let cached = Engine::with_kv_cache(&rt);
    let layout = tok.layout_prompt(&cfg, "Q: 12+7=?").unwrap();
    let p = StaticThreshold::new(0.9);
    let a = plain.decode(layout.clone(), &p).unwrap();
    let b = cached.decode(layout, &p).unwrap();
    for r in [&a, &b] {
        assert!(r.gen_tokens(&cfg).iter().all(|&t| t != cfg.mask_id));
    }
    assert_eq!(b.full_passes, cfg.num_blocks);
    assert!(b.window_passes > 0);
    // the approximation must not blow decoding up
    assert!(b.steps <= 3 * a.steps.max(6), "cached {} vs plain {}", b.steps, a.steps);
}

#[test]
fn sequential_baseline_steps_exact() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let engine = Engine::new(&rt);
    let layout = tok.layout_prompt(&cfg, "Q: 2+2=?").unwrap();
    let res = engine.decode(layout, &SequentialTopK::new(1)).unwrap();
    assert_eq!(res.steps, cfg.gen_len);
}
