//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These exercise the full L1+L2+L3 composition: HLO text emitted by
//! python (containing the Pallas kernels) loaded, compiled and executed
//! from Rust, cross-validated against a golden vector computed by JAX
//! (`artifacts/golden_fwd.json`, written at build time) — plus the cache
//! residency contract (DESIGN.md §10): device-resident decode must be
//! token-identical to the host round-trip path while performing **zero**
//! per-step host K/V transfers.
//!
//! All tests skip gracefully when artifacts are absent (pre-`make
//! artifacts` builds).

use osdt::cache::Residency;
use osdt::decode::Engine;
use osdt::model::ModelConfig;
use osdt::policy::{FactorThreshold, HostTraced, SequentialTopK, StaticThreshold};
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::util::json::Json;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("model_config.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn load() -> (ModelConfig, ModelRuntime, Tokenizer) {
    let dir = artifacts_dir().unwrap();
    let cfg = ModelConfig::load(&dir).unwrap();
    let rt = ModelRuntime::load(&cfg).unwrap();
    let tok = Tokenizer::from_config(&cfg).unwrap();
    (cfg, rt, tok)
}

#[test]
fn fwd_conf_matches_python_golden() {
    let dir = require_artifacts!();
    let golden_path = dir.join("golden_fwd.json");
    if !golden_path.exists() {
        eprintln!("skipping: golden_fwd.json not present");
        return;
    }
    let gold = Json::parse(&std::fs::read_to_string(golden_path).unwrap()).unwrap();
    let prompt = gold.get("prompt").unwrap().as_str().unwrap();
    let want_conf: Vec<f64> = gold
        .get("conf_64_72")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    let want_arg: Vec<u32> = gold
        .get("argmax_64_72")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u32().unwrap())
        .collect();

    let (cfg, rt, tok) = load();
    let layout = tok.layout_prompt(&cfg, prompt).unwrap();
    let out = rt.fwd_conf(&[layout.as_slice()]).unwrap();
    for i in 0..8 {
        let got = f64::from(out.conf_row(0)[64 + i]);
        assert!(
            (got - want_conf[i]).abs() < 1e-4,
            "conf[{i}]: rust {got} vs jax {}",
            want_conf[i]
        );
        assert_eq!(out.argmax_row(0)[64 + i], want_arg[i], "argmax[{i}]");
    }
}

#[test]
fn batch_variants_agree_with_b1() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let l1 = tok.layout_prompt(&cfg, "Q: 5+6=?").unwrap();
    let l2 = tok.layout_prompt(&cfg, "Q: 9-2=?").unwrap();
    let solo1 = rt.fwd_conf(&[l1.as_slice()]).unwrap();
    let solo2 = rt.fwd_conf(&[l2.as_slice()]).unwrap();
    let both = rt.fwd_conf(&[l1.as_slice(), l2.as_slice()]).unwrap(); // compiled b2 variant
    for (a, b) in [
        (solo1.conf_row(0), both.conf_row(0)),
        (solo2.conf_row(0), both.conf_row(1)),
    ] {
        for i in 0..cfg.seq_len {
            assert!(
                (a[i] - b[i]).abs() < 1e-5,
                "batched conf differs at {i}: {} vs {}",
                a[i],
                b[i]
            );
        }
    }
    assert_eq!(solo1.argmax_row(0), both.argmax_row(0));
    assert_eq!(solo2.argmax_row(0), both.argmax_row(1));
}

#[test]
fn oversized_fwd_conf_batch_chunks_identically() {
    // n > the largest compiled variant must chunk, not bail (and the rows
    // must match solo passes exactly)
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let n = rt.max_batch() + 2;
    let layouts: Vec<Vec<u32>> = (0..n)
        .map(|i| tok.layout_prompt(&cfg, &format!("Q: {i}+2=?")).unwrap())
        .collect();
    let refs: Vec<&[u32]> = layouts.iter().map(Vec::as_slice).collect();
    let all = rt.fwd_conf(&refs).unwrap();
    assert_eq!(all.len(), n);
    for (i, l) in layouts.iter().enumerate() {
        let solo = rt.fwd_conf(&[l.as_slice()]).unwrap();
        assert_eq!(all.argmax_row(i), solo.argmax_row(0), "row {i}");
    }
}

#[test]
fn full_kv_conf_matches_fwd_conf() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    rt.set_residency(Residency::Host); // inspect the downloaded payload
    let layout = tok.layout_prompt(&cfg, "Q: class of foo?").unwrap();
    let plain = rt.fwd_conf(&[layout.as_slice()]).unwrap();
    let (kvout, cache) = rt.fwd_full_kv(&layout).unwrap();
    for i in 0..cfg.seq_len {
        assert!(
            (plain.conf_row(0)[i] - kvout.conf_row(0)[i]).abs() < 1e-5,
            "conf differs at {i}"
        );
    }
    assert_eq!(plain.argmax_row(0), kvout.argmax_row(0));
    let kv = cache.as_host().expect("host residency mints host handles");
    let want: usize = cache.dims().iter().product();
    assert_eq!(kv.k.len(), want);
    assert!(kv.k.iter().all(|x| x.is_finite()));
}

#[test]
fn window_matches_full_on_fresh_cache() {
    // Fast-dLLM DualCache exactness at step 0 of a block, on the real
    // model — at both cache residencies
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let layout = tok.layout_prompt(&cfg, "op: rev | in: abcd").unwrap();
    for residency in [Residency::Host, Residency::Device] {
        rt.set_residency(residency);
        let (full, cache) = rt.fwd_full_kv(&layout).unwrap();
        assert_eq!(cache.residency(), residency);
        for b in 0..cfg.num_blocks {
            let range = cfg.block_range(b);
            let window: Vec<u32> = layout[range.clone()].to_vec();
            let out = rt.fwd_window(&window, range.start, &cache).unwrap();
            for (i, pos) in range.clone().enumerate() {
                assert!(
                    (out.conf_row(0)[i] - full.conf_row(0)[pos]).abs() < 1e-4,
                    "{residency:?} block {b} pos {pos}: window {} vs full {}",
                    out.conf_row(0)[i],
                    full.conf_row(0)[pos]
                );
                assert_eq!(
                    out.argmax_row(0)[i],
                    full.argmax_row(0)[pos],
                    "{residency:?} block {b} pos {pos}"
                );
            }
        }
    }
}

#[test]
fn decode_fills_gen_region_real_model() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let engine = Engine::new(&rt);
    let layout = tok.layout_prompt(&cfg, "Q: 3+4=?").unwrap();
    let res = engine.decode(layout, &StaticThreshold::new(0.9)).unwrap();
    let gen = res.gen_tokens(&cfg);
    assert!(gen.iter().all(|&t| t != cfg.mask_id), "masks remain");
    assert!(res.steps >= cfg.num_blocks);
    assert!(res.steps <= cfg.gen_len);
    let text = tok.decode_until_eos(gen);
    // trained model should answer the sum with its worked-steps format
    eprintln!("decoded: {text}");
    assert!(text.contains("A:"), "unexpected decode: {text}");
}

#[test]
fn cached_decode_close_to_uncached_real_model() {
    // The dual cache is an approximation on a real model (stale prefix /
    // suffix K/V within a block) — but with static τ=0.9 both paths must
    // produce valid completions and comparable step counts.
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let plain = Engine::new(&rt);
    let cached = Engine::with_kv_cache(&rt);
    let layout = tok.layout_prompt(&cfg, "Q: 12+7=?").unwrap();
    let p = StaticThreshold::new(0.9);
    let a = plain.decode(layout.clone(), &p).unwrap();
    let b = cached.decode(layout, &p).unwrap();
    for r in [&a, &b] {
        assert!(r.gen_tokens(&cfg).iter().all(|&t| t != cfg.mask_id));
    }
    assert_eq!(b.full_passes, cfg.num_blocks);
    assert!(b.window_passes > 0);
    // the approximation must not blow decoding up
    assert!(b.steps <= 3 * a.steps.max(6), "cached {} vs plain {}", b.steps, a.steps);
}

#[test]
fn device_residency_token_identical_with_zero_kv_transfer() {
    // The tentpole acceptance test (solo): device-resident cached decode
    // must produce exactly the host path's tokens while moving zero K/V
    // bytes across the host boundary — the K/V round trip is untimed
    // compute, not an approximation.
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let layout = tok.layout_prompt(&cfg, "Q: 8+5=?").unwrap();
    let p = StaticThreshold::new(0.9);
    let cached = Engine::with_kv_cache(&rt);

    rt.set_residency(Residency::Host);
    let s0 = rt.stats();
    let host = cached.decode(layout.clone(), &p).unwrap();
    let s1 = rt.stats();
    assert!(
        s1.cache_upload_bytes > s0.cache_upload_bytes,
        "host path must upload K/V per window step"
    );
    assert!(s1.cache_download_bytes > s0.cache_download_bytes);

    rt.set_residency(Residency::Device);
    let s2 = rt.stats();
    let dev = cached.decode(layout, &p).unwrap();
    let s3 = rt.stats();
    assert_eq!(dev.tokens, host.tokens, "residency must not change tokens");
    assert_eq!(dev.steps, host.steps);
    assert_eq!(
        s3.cache_upload_bytes, s2.cache_upload_bytes,
        "device path uploaded K/V bytes"
    );
    assert_eq!(
        s3.cache_download_bytes, s2.cache_download_bytes,
        "device path downloaded K/V bytes"
    );
    // device decode still transfers tokens + conf rows, but strictly fewer
    // total bytes than the host round trip
    let host_bytes = s1.transfer_bytes() - s0.transfer_bytes();
    let dev_bytes = s3.transfer_bytes() - s2.transfer_bytes();
    assert!(
        dev_bytes < host_bytes,
        "device path must reduce bytes/decode: {dev_bytes} !< {host_bytes}"
    );
}

#[test]
fn batched_device_decode_zero_kv_uploads_and_identity() {
    // The tentpole acceptance test (batched): cached batched decode on the
    // device path performs zero per-step host K/V uploads and stays
    // token-identical to solo cached decode.
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let p = StaticThreshold::new(0.9);
    let cached = Engine::with_kv_cache(&rt);
    let layouts: Vec<Vec<u32>> = (0..3)
        .map(|i| tok.layout_prompt(&cfg, &format!("Q: {i}+6=?")).unwrap())
        .collect();

    rt.set_residency(Residency::Device);
    let solos: Vec<_> = layouts
        .iter()
        .map(|l| cached.decode(l.clone(), &p).unwrap())
        .collect();
    let s0 = rt.stats();
    let policies: Vec<&dyn osdt::policy::Policy> = vec![&p, &p, &p];
    let batched = cached.decode_batch(layouts, &policies).unwrap();
    let s1 = rt.stats();
    assert_eq!(
        s1.cache_upload_bytes, s0.cache_upload_bytes,
        "batched device decode uploaded K/V bytes"
    );
    assert_eq!(s1.cache_download_bytes, s0.cache_download_bytes);
    for (b, s) in batched.iter().zip(&solos) {
        assert_eq!(b.tokens, s.tokens);
        assert_eq!(b.steps, s.steps);
    }
    // the device path must also recycle buffers: every minted device
    // handle is reclaimed once its sequence retires
    let pool = rt.pool().stats();
    assert!(pool.minted_device > 0);
    assert!(pool.reclaimed_device + pool.dropped >= pool.minted_device);
}

#[test]
fn fused_accept_zero_conf_row_downloads_and_token_identity() {
    // The fused-acceptance acceptance test (DESIGN.md §11): with a
    // fusible policy on the device-residency path, steady-state window
    // steps perform ZERO full confidence-row downloads — every in-block
    // decision runs through Entry::Accept, whose per-step D2H is compact —
    // and the tokens are identical to the host-decision path.
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    if cfg.variant("fwd_window_accept_b1").is_err() {
        eprintln!("skipping: artifacts predate the fused accept variants");
        return;
    }
    rt.set_residency(Residency::Device);
    let cached = Engine::with_kv_cache(&rt);

    for (name, fused_p, host_p) in [
        (
            "static",
            Box::new(StaticThreshold::new(0.9)) as Box<dyn osdt::policy::Policy>,
            Box::new(HostTraced(StaticThreshold::new(0.9)))
                as Box<dyn osdt::policy::Policy>,
        ),
        (
            "factor",
            Box::new(FactorThreshold::new(0.95)),
            Box::new(HostTraced(FactorThreshold::new(0.95))),
        ),
    ] {
        let layout = tok.layout_prompt(&cfg, "Q: 6+3=?").unwrap();
        let host = cached.decode(layout.clone(), host_p.as_ref()).unwrap();
        let s0 = rt.stats();
        let dev = cached.decode(layout, fused_p.as_ref()).unwrap();
        let s1 = rt.stats();

        assert_eq!(dev.tokens, host.tokens, "{name}: fusion changed tokens");
        assert_eq!(dev.steps, host.steps, "{name}: fusion changed steps");
        assert!(dev.window_passes > 0, "{name}: no window steps exercised");

        // zero full confidence-row downloads on window steps: the Window
        // entry stays completely idle while Accept carries the decode
        assert_eq!(
            s1.window.calls, s0.window.calls,
            "{name}: fused decode ran plain window passes"
        );
        assert_eq!(
            s1.window.download_bytes, s0.window.download_bytes,
            "{name}: fused decode downloaded confidence rows"
        );
        let accept_calls = s1.accept.calls - s0.accept.calls;
        assert!(accept_calls > 0, "{name}: no fused passes executed");

        // compactness: mean accept D2H per window step must be far below
        // one full (conf f32 + argmax i32) row pair
        let accept_dl = s1.accept.download_bytes - s0.accept.download_bytes;
        let per_step = accept_dl / (dev.window_passes as u64).max(1);
        let full_rows = 2 * 4 * cfg.block_len as u64;
        assert!(
            per_step < full_rows,
            "{name}: accept D2H {per_step} B/step !< full rows {full_rows} B"
        );
        // and zero K/V traffic on top (device residency, PR 3 invariant)
        assert_eq!(s1.cache_upload_bytes, s0.cache_upload_bytes, "{name}");
    }
}

#[test]
fn fused_batched_decode_matches_solo_with_compact_transfers() {
    // batched fused decode: kv_gather -> fwd_window_accept_b{B} with the
    // stacked caches donated; tokens identical to solo fused decode and
    // the Window entry still never fires
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    if cfg.variant("fwd_window_accept_b2").is_err() {
        eprintln!("skipping: artifacts predate the batched accept variants");
        return;
    }
    rt.set_residency(Residency::Device);
    let cached = Engine::with_kv_cache(&rt);
    let p = StaticThreshold::new(0.9);
    let layouts: Vec<Vec<u32>> = (0..3)
        .map(|i| tok.layout_prompt(&cfg, &format!("Q: {i}+4=?")).unwrap())
        .collect();
    let solos: Vec<_> = layouts
        .iter()
        .map(|l| cached.decode(l.clone(), &p).unwrap())
        .collect();
    let s0 = rt.stats();
    let policies: Vec<&dyn osdt::policy::Policy> = vec![&p, &p, &p];
    let batched = cached.decode_batch(layouts, &policies).unwrap();
    let s1 = rt.stats();
    for (b, s) in batched.iter().zip(&solos) {
        assert_eq!(b.tokens, s.tokens);
        assert_eq!(b.steps, s.steps);
    }
    assert_eq!(
        s1.window.calls, s0.window.calls,
        "batched fused decode must not fall back to plain window passes"
    );
    assert!(s1.accept.calls > s0.accept.calls);
    assert_eq!(s1.cache_upload_bytes, s0.cache_upload_bytes);
}

#[test]
fn sequential_baseline_steps_exact() {
    let _ = require_artifacts!();
    let (cfg, rt, tok) = load();
    let engine = Engine::new(&rt);
    let layout = tok.layout_prompt(&cfg, "Q: 2+2=?").unwrap();
    let res = engine.decode(layout, &SequentialTopK::new(1)).unwrap();
    assert_eq!(res.steps, cfg.gen_len);
}
