//! End-to-end observability contract (DESIGN.md §12):
//!
//! * drive a representative traffic mix through a coordinator, scrape the
//!   standalone HTTP `/metrics` endpoint, and validate the body against a
//!   miniature strict-Prometheus parser (HELP/TYPE per family, sample
//!   naming, monotone cumulative buckets, `+Inf` == `_count`);
//! * cross-check the three sources of truth — `metrics::catalog()`, the
//!   rendered exposition, and `METRICS.md` — in both directions so none
//!   of them can rot independently.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read as _, Write as _};
use std::net::TcpStream;

use osdt::coordinator::{Coordinator, CoordinatorConfig};
use osdt::metrics::http::MetricsServer;
use osdt::metrics::{catalog, expo, MetricKind};
use osdt::model::fixtures::tiny_config;
use osdt::policy::{Acquired, DynamicMode, Metric, ProfileKey};
use osdt::sim::SimModel;

const OSDT_SPEC: &str = "osdt:block:q1:0.75:0.2";

fn key() -> ProfileKey {
    ProfileKey::new("synth-math", DynamicMode::Block, Metric::Q1)
}

/// Representative traffic: success, calibration, reuse, failure,
/// invalidation churn, lease contention, steal, and drift observation —
/// touching as many metric families as the sim stack can reach.
fn smoke_coordinator() -> Coordinator {
    let c = Coordinator::start(CoordinatorConfig::default(), tiny_config(), |_| {
        Ok(SimModel::math_like(5))
    })
    .unwrap();
    // static success + OSDT calibrate/reuse + failure + recalibration
    assert!(c.generate("synth-math", "Q: 1+2=?", "static:0.9").unwrap().error.is_none());
    assert!(c.generate("synth-math", "Q: 2+3=?", OSDT_SPEC).unwrap().calibrated);
    assert!(!c.generate("synth-math", "Q: 3+4=?", OSDT_SPEC).unwrap().calibrated);
    assert!(c.generate("synth-math", "Q: 4+5=?", "warp:9").unwrap().error.is_some());
    assert!(c.registry.invalidate(&key()));
    assert!(c.generate("synth-math", "Q: 5+6=?", OSDT_SPEC).unwrap().calibrated);

    // registry-direct churn on a disjoint key: contention (waits), an
    // abandoned lease, a steal with a superseding late drop
    let k2 = ProfileKey::new("synth-math", DynamicMode::StepBlock, Metric::Median);
    let lease = match c.registry.acquire(&k2) {
        Acquired::Lease(l) => l,
        _ => panic!("fresh key must grant the lease"),
    };
    assert!(matches!(c.registry.acquire(&k2), Acquired::InFlight));
    let thief = match c.registry.acquire_stealing(&k2) {
        Acquired::Lease(l) => l,
        _ => panic!("stealing acquire must take the lease"),
    };
    drop(lease); // superseded by the thief
    drop(thief); // abandoned: k2 never calibrates

    // drift observation against the calibrated profile's reference
    let mut divergent =
        osdt::policy::CalibrationTrace::new(tiny_config().num_blocks);
    for b in 0..tiny_config().num_blocks {
        divergent.record(b, 0, &[0.95, 0.02]);
        divergent.record(b, 1, &[0.01]);
    }
    let epoch = c.registry.get(&key()).unwrap().epoch;
    c.registry.observe(&key(), epoch, &divergent);
    c
}

// ---------------------------------------------------------------------------
// Miniature strict-Prometheus parser
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Family {
    kind: String,
    has_help: bool,
    /// (sample name, `le` label if any, value) in exposition order.
    samples: Vec<(String, Option<String>, f64)>,
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().unwrap().is_ascii_alphabetic()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Family a sample line belongs to: histogram samples carry a
/// `_bucket`/`_sum`/`_count` suffix, everything else is the family itself.
fn family_of<'a>(
    sample: &'a str,
    families: &BTreeMap<String, Family>,
) -> Option<(String, &'a str)> {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = sample.strip_suffix(suffix) {
            if families.get(base).is_some_and(|f| f.kind == "histogram") {
                return Some((base.to_string(), suffix));
            }
        }
    }
    families.contains_key(sample).then(|| (sample.to_string(), ""))
}

fn parse_exposition(body: &str) -> BTreeMap<String, Family> {
    let mut families: BTreeMap<String, Family> = BTreeMap::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest.split_once(' ').expect("TYPE needs a kind");
            assert!(valid_name(name), "bad family name {name:?}");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "bad TYPE {kind:?} for {name}"
            );
            let fam = families.entry(name.to_string()).or_default();
            assert!(fam.kind.is_empty(), "duplicate TYPE for {name}");
            fam.kind = kind.to_string();
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest.split_once(' ').expect("HELP needs text");
            assert!(!help.trim().is_empty(), "empty HELP for {name}");
            families.entry(name.to_string()).or_default().has_help = true;
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment line {line:?}");
        // sample: `name value` or `name{le="x"} value`
        let (name_labels, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("bad sample {line:?}"));
        let (sample, le) = match name_labels.split_once('{') {
            Some((n, labels)) => {
                let labels = labels.strip_suffix('}').expect("unclosed labels");
                let le = labels
                    .strip_prefix("le=\"")
                    .and_then(|v| v.strip_suffix('"'))
                    .unwrap_or_else(|| panic!("only le labels expected: {line:?}"));
                (n, Some(le.to_string()))
            }
            None => (name_labels, None),
        };
        assert!(valid_name(sample), "bad sample name {sample:?}");
        let v = if value == "+Inf" {
            f64::INFINITY
        } else {
            value.parse::<f64>().unwrap_or_else(|_| panic!("bad value {line:?}"))
        };
        let (family, _suffix) = family_of(sample, &families)
            .unwrap_or_else(|| panic!("sample {sample} has no TYPE line"));
        families
            .get_mut(&family)
            .unwrap()
            .samples
            .push((sample.to_string(), le, v));
    }
    families
}

fn validate(families: &BTreeMap<String, Family>) {
    for (name, fam) in families {
        assert!(fam.has_help, "{name} missing HELP");
        assert!(!fam.kind.is_empty(), "{name} missing TYPE");
        assert!(!fam.samples.is_empty(), "{name} declared but empty");
        match fam.kind.as_str() {
            "counter" => {
                assert!(name.ends_with("_total"), "counter {name} lacks _total");
                for (_, _, v) in &fam.samples {
                    assert!(*v >= 0.0, "counter {name} negative");
                }
            }
            "gauge" => assert!(!name.ends_with("_total"), "gauge {name}"),
            "histogram" => {
                let buckets: Vec<(f64, f64)> = fam
                    .samples
                    .iter()
                    .filter(|(s, _, _)| s.ends_with("_bucket"))
                    .map(|(_, le, v)| {
                        let le = le.as_ref().expect("bucket without le");
                        let b = if le == "+Inf" {
                            f64::INFINITY
                        } else {
                            le.parse::<f64>().unwrap()
                        };
                        (b, *v)
                    })
                    .collect();
                assert!(buckets.len() >= 2, "{name} needs buckets");
                for w in buckets.windows(2) {
                    assert!(w[1].0 > w[0].0, "{name} le not ascending");
                    assert!(w[1].1 >= w[0].1, "{name} buckets not cumulative");
                }
                let (last_le, last_v) = *buckets.last().unwrap();
                assert!(last_le.is_infinite(), "{name} missing +Inf bucket");
                let count = fam
                    .samples
                    .iter()
                    .find(|(s, _, _)| s.ends_with("_count"))
                    .map(|(_, _, v)| *v)
                    .unwrap_or_else(|| panic!("{name} missing _count"));
                assert_eq!(last_v, count, "{name} +Inf != _count");
                assert!(
                    fam.samples.iter().any(|(s, _, _)| s.ends_with("_sum")),
                    "{name} missing _sum"
                );
            }
            other => panic!("{name}: bad kind {other}"),
        }
    }
}

/// Backticked `osdt_*` tokens in METRICS.md — the documented family set.
fn documented_families() -> BTreeSet<String> {
    let doc = include_str!("../../METRICS.md");
    doc.split('`')
        .skip(1)
        .step_by(2)
        .filter(|tok| {
            tok.starts_with("osdt_")
                && tok.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
        })
        .map(String::from)
        .collect()
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn endpoint_serves_valid_prometheus_under_load() {
    let c = smoke_coordinator();
    // worker loops publish their final deltas just after responding
    std::thread::sleep(std::time::Duration::from_millis(80));
    let srv = MetricsServer::start(
        "127.0.0.1:0",
        vec![c.metrics.clone(), c.registry.metrics().clone()],
    )
    .unwrap();

    let mut s = TcpStream::connect(srv.addr).unwrap();
    write!(s, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf.split_once("\r\n\r\n").unwrap();
    assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
    assert!(head.contains(expo::CONTENT_TYPE), "{head}");

    let families = parse_exposition(body);
    validate(&families);

    // the traffic mix must surface the request lifecycle, the calibration
    // lifecycle, the failure counters, and the latency histograms
    for required in [
        "osdt_process_uptime_seconds",
        "osdt_metrics_scrapes_total",
        "osdt_requests_completed_total",
        "osdt_requests_failed_total",
        "osdt_tokens_generated_total",
        "osdt_scheduler_steps_total",
        "osdt_request_latency_seconds",
        "osdt_request_ttft_seconds",
        "osdt_admission_wait_seconds",
        "osdt_accepted_tokens_per_step",
        "osdt_batch_occupancy_per_step",
        "osdt_calibrations_total",
        "osdt_calibrations_completed_total",
        "osdt_recalibrations_total",
        "osdt_profile_hits_total",
        "osdt_profile_waits_total",
        "osdt_profile_invalidations_total",
        "osdt_leases_granted_total",
        "osdt_leases_abandoned_total",
        "osdt_leases_superseded_total",
        "osdt_lease_takeovers_total",
        "osdt_drift_events_total",
        "osdt_profile_signature_cosine",
    ] {
        assert!(families.contains_key(required), "missing family {required}");
    }

    // TTFT (enqueue → first commit) is bounded by admission wait (enqueue
    // → admission) plus request latency (admission → response), per
    // request and therefore in aggregate
    let sum_of = |fam: &str| {
        families[fam]
            .samples
            .iter()
            .find(|(s, _, _)| s.ends_with("_sum"))
            .map(|(_, _, v)| *v)
            .unwrap()
    };
    assert!(
        sum_of("osdt_request_ttft_seconds")
            <= sum_of("osdt_admission_wait_seconds")
                + sum_of("osdt_request_latency_seconds"),
        "ttft sum exceeds admission wait + latency sum"
    );
    srv.stop();
    c.shutdown();
}

/// catalog() ⊆/⊇ METRICS.md and exposition ⊆ catalog(): the three views of
/// the metric surface cannot drift apart.
#[test]
fn metrics_doc_cross_check() {
    let doc = documented_families();
    let declared: BTreeSet<String> =
        catalog().iter().map(|s| s.exposed.to_string()).collect();

    let undocumented: Vec<_> = declared.difference(&doc).collect();
    assert!(
        undocumented.is_empty(),
        "declared in catalog() but missing from METRICS.md: {undocumented:?}"
    );
    let phantom: Vec<_> = doc.difference(&declared).collect();
    assert!(
        phantom.is_empty(),
        "documented in METRICS.md but not in catalog(): {phantom:?}"
    );

    // everything the smoke traffic emits resolves to a declared family —
    // an undeclared internal name would render with a derived family and
    // fail here, which is what keeps catalog() honest
    let c = smoke_coordinator();
    std::thread::sleep(std::time::Duration::from_millis(80));
    let body = expo::render_prometheus(&[&c.metrics, c.registry.metrics()]);
    let families = parse_exposition(&body);
    for name in families.keys() {
        assert!(
            declared.contains(name),
            "emitted family {name} is not declared in metrics::catalog()"
        );
    }
    assert!(
        !body.contains("Undeclared metric"),
        "exposition contains undeclared metrics:\n{body}"
    );

    // help text parity: catalog kinds match the exposition's TYPE lines
    for spec in catalog() {
        if let Some(fam) = families.get(spec.exposed) {
            let want = match spec.kind {
                MetricKind::Counter => "counter",
                MetricKind::Gauge => "gauge",
                MetricKind::Histogram => "histogram",
            };
            assert_eq!(fam.kind, want, "{} kind mismatch", spec.exposed);
        }
    }
    c.shutdown();
}
