//! Integration tests of the continuous-batching step scheduler (DESIGN.md
//! §5) over the analytic simulator: mid-flight admission, independent
//! retirement, and the core correctness bar — scheduling decisions never
//! change per-sequence results, cache on or off.

use osdt::cache::CacheConfig;
use osdt::decode::{DecodeResult, Engine, ForwardModel, StepScheduler};
use osdt::policy::{FactorThreshold, Policy, SequentialTopK, StaticThreshold};
use osdt::sim::SimModel;
use osdt::util::prop;
use osdt::util::rng::Rng;

fn by_id(results: &[(u64, DecodeResult)], id: u64) -> &DecodeResult {
    &results
        .iter()
        .find(|(i, _)| *i == id)
        .unwrap_or_else(|| panic!("sequence {id} missing"))
        .1
}

#[test]
fn mid_flight_admission_joins_at_next_step_boundary() {
    let m = SimModel::math_like(21);
    let eng = Engine::new(&m);
    let p = StaticThreshold::new(0.9);
    let solo_a = eng.decode(m.layout_from_seed(1), &p).unwrap();
    let solo_b = eng.decode(m.layout_from_seed(2), &p).unwrap();
    assert!(solo_a.steps > 3, "test needs a decode longer than 3 steps");

    let mut sched: StepScheduler<'_, SimModel, &dyn Policy> =
        StepScheduler::new(&m, CacheConfig::disabled(), 4);
    sched.admit(0, m.layout_from_seed(1), &p).unwrap();
    let mut retired = Vec::new();
    for _ in 0..3 {
        let r = sched.step().unwrap();
        assert_eq!(r.occupancy, 1, "A decodes alone before B arrives");
        retired.extend(r.retired);
    }
    // B arrives mid-flight and must join the very next step
    sched.admit(1, m.layout_from_seed(2), &p).unwrap();
    let r = sched.step().unwrap();
    assert_eq!(r.occupancy, 2, "B must join at the next step boundary");
    retired.extend(r.retired);
    retired.extend(sched.drain().unwrap());

    // joining a running batch changes neither sequence's outcome
    let a = by_id(&retired, 0);
    let b = by_id(&retired, 1);
    assert_eq!(a.tokens, solo_a.tokens);
    assert_eq!(a.steps, solo_a.steps);
    assert_eq!(b.tokens, solo_b.tokens);
    assert_eq!(b.steps, solo_b.steps);
}

#[test]
fn finished_sequences_retire_without_blocking_peers() {
    let m = SimModel::math_like(22);
    let cfg = m.config().clone();
    let fast = StaticThreshold::new(0.5); // lax: a few steps per block
    let slow = SequentialTopK::new(1); // exactly gen_len steps
    let mut sched: StepScheduler<'_, SimModel, &dyn Policy> =
        StepScheduler::new(&m, CacheConfig::disabled(), 4);
    sched
        .admit(0, m.layout_from_seed(3), &fast as &dyn Policy)
        .unwrap();
    sched
        .admit(1, m.layout_from_seed(4), &slow as &dyn Policy)
        .unwrap();

    let mut fast_done_at = None;
    let mut slow_done_at = None;
    let mut step = 0usize;
    while !sched.is_idle() {
        step += 1;
        assert!(step <= 2 * cfg.gen_len, "scheduler failed to terminate");
        let r = sched.step().unwrap();
        for (id, _res) in r.retired {
            match id {
                0 => fast_done_at = Some(step),
                _ => slow_done_at = Some(step),
            }
        }
        if fast_done_at.is_some() && slow_done_at.is_none() {
            assert_eq!(
                sched.active_len(),
                1,
                "retired sequence must leave the batch immediately"
            );
        }
    }
    let fast_done = fast_done_at.expect("fast sequence retired");
    let slow_done = slow_done_at.expect("slow sequence retired");
    assert!(
        fast_done < slow_done,
        "fast ({fast_done}) must not wait for slow ({slow_done})"
    );
    assert_eq!(slow_done, cfg.gen_len, "slow peer keeps its exact step count");
}

#[test]
fn cached_mid_flight_admission_is_token_identical() {
    let m = SimModel::qa_like(23);
    let eng = Engine::with_kv_cache(&m);
    let p = StaticThreshold::new(0.85);
    let solo_a = eng.decode(m.layout_from_seed(5), &p).unwrap();
    let solo_b = eng.decode(m.layout_from_seed(6), &p).unwrap();

    let mut sched: StepScheduler<'_, SimModel, &dyn Policy> =
        StepScheduler::new(&m, CacheConfig::block_boundary(), 4);
    sched.admit(0, m.layout_from_seed(5), &p).unwrap();
    sched.step().unwrap();
    sched.step().unwrap();
    sched.admit(1, m.layout_from_seed(6), &p).unwrap();
    let results = sched.drain().unwrap();
    let a = by_id(&results, 0);
    let b = by_id(&results, 1);
    assert_eq!(a.tokens, solo_a.tokens);
    assert_eq!(a.window_passes, solo_a.window_passes);
    assert_eq!(b.tokens, solo_b.tokens);
    assert_eq!(b.full_passes, solo_b.full_passes);
}

#[test]
fn mixed_policy_batch_matches_solo_under_every_cache_mode() {
    let m = SimModel::code_like(24);
    for cache in [
        CacheConfig::disabled(),
        CacheConfig::block_boundary(),
        CacheConfig::with_refresh_interval(3),
    ] {
        let eng = Engine::with_cache(&m, cache);
        let policies: Vec<Box<dyn Policy>> = vec![
            Box::new(StaticThreshold::new(0.9)),
            Box::new(SequentialTopK::new(2)),
            Box::new(StaticThreshold::new(0.7)),
            Box::new(FactorThreshold::new(0.95)),
        ];
        let layouts: Vec<Vec<u32>> =
            (0..policies.len()).map(|i| m.layout_from_seed(40 + i as u64)).collect();
        let solos: Vec<DecodeResult> = layouts
            .iter()
            .zip(&policies)
            .map(|(l, p)| eng.decode(l.clone(), p.as_ref()).unwrap())
            .collect();
        let refs: Vec<&dyn Policy> = policies.iter().map(|p| p.as_ref()).collect();
        let batched = eng.decode_batch(layouts, &refs).unwrap();
        for (i, (b, s)) in batched.iter().zip(&solos).enumerate() {
            assert_eq!(b.tokens, s.tokens, "cache {cache:?} seq {i}: tokens");
            assert_eq!(b.steps, s.steps, "cache {cache:?} seq {i}: steps");
            assert_eq!(
                b.full_passes, s.full_passes,
                "cache {cache:?} seq {i}: full passes"
            );
            assert_eq!(
                b.window_passes, s.window_passes,
                "cache {cache:?} seq {i}: window passes"
            );
        }
    }
}

#[test]
fn overflow_admissions_queue_fifo_and_all_retire() {
    let m = SimModel::math_like(25);
    let p = StaticThreshold::new(0.8);
    let n = m.max_batch() + 3;
    let mut sched: StepScheduler<'_, SimModel, &dyn Policy> =
        StepScheduler::new(&m, CacheConfig::disabled(), m.max_batch());
    for i in 0..n {
        sched
            .admit(i as u64, m.layout_from_seed(60 + i as u64), &p as &dyn Policy)
            .unwrap();
    }
    let mut saw_full_occupancy = false;
    let mut results = Vec::new();
    while !sched.is_idle() {
        let r = sched.step().unwrap();
        saw_full_occupancy |= r.occupancy == m.max_batch();
        assert!(r.occupancy <= m.max_batch());
        results.extend(r.retired);
    }
    assert!(saw_full_occupancy, "slots must fill up under overflow load");
    assert_eq!(results.len(), n);
    for i in 0..n {
        let res = by_id(&results, i as u64);
        let solo = Engine::new(&m)
            .decode(m.layout_from_seed(60 + i as u64), &p)
            .unwrap();
        assert_eq!(res.tokens, solo.tokens, "seq {i}");
    }
}

#[test]
fn prop_batched_matches_solo_across_settings() {
    // random cache modes, thresholds, batch sizes (including overflow):
    // continuous batching is invisible in per-sequence results
    prop::forall(
        "scheduler-transparency",
        30,
        |r: &mut Rng| {
            (
                r.next_u64(),
                r.below(3),
                0.5 + r.next_f64() * 0.45,
                2 + r.below(4) as usize,
            )
        },
        |&(seed, cache_kind, tau, n)| {
            let m = SimModel::qa_like(seed);
            let cache = match cache_kind {
                0 => CacheConfig::disabled(),
                1 => CacheConfig::block_boundary(),
                _ => CacheConfig::with_refresh_interval(2),
            };
            let eng = Engine::with_cache(&m, cache);
            let p = StaticThreshold::new(tau);
            let layouts: Vec<Vec<u32>> =
                (0..n).map(|i| m.layout_from_seed(seed ^ (i as u64))).collect();
            let solos = layouts
                .iter()
                .map(|l| eng.decode(l.clone(), &p))
                .collect::<Result<Vec<_>, _>>()
                .map_err(|e| e.to_string())?;
            let refs: Vec<&dyn Policy> = (0..n).map(|_| &p as &dyn Policy).collect();
            let batched = eng
                .decode_batch(layouts, &refs)
                .map_err(|e| e.to_string())?;
            for (i, (b, s)) in batched.iter().zip(&solos).enumerate() {
                if b.tokens != s.tokens {
                    return Err(format!("seq {i}: tokens differ"));
                }
                if b.steps != s.steps {
                    return Err(format!("seq {i}: {} vs {} steps", b.steps, s.steps));
                }
            }
            Ok(())
        },
    );
}
