//! Integration tests of profile-guided step elision (DESIGN.md §14): the
//! planner skips window passes the calibrated acceptance trajectory
//! predicts are empty, and the correctness bar is token identity — an
//! eliding decode commits exactly the tokens the non-eliding schedule
//! would, in strictly fewer window passes when the predictions hold.
//! Mispredictions are detected, bounded, and fed to the profile registry
//! as drift evidence (§9).
//!
//! All tests run over the plateau simulator: confidence is a pure function
//! of position (decode-progress independent), so hand-built profiles can
//! stage predictable empty runs without the calibration round trip.

use std::sync::Arc;
use std::time::Duration;

use osdt::cache::CacheConfig;
use osdt::coordinator::{Coordinator, CoordinatorConfig};
use osdt::decode::{DecodeResult, StepScheduler};
use osdt::model::fixtures::tiny_config;
use osdt::policy::{
    signature_cosine, Acquired, DynamicMode, Metric, Osdt, Policy, Profile,
    ProfileKey, ProfileRegistry, RegistryConfig, DEFAULT_ELIDE_FLOOR,
};
use osdt::sim::SimModel;
use osdt::util::prop;
use osdt::util::rng::Rng;

const SPEC: &str = "osdt:step-block:q1:1:0";

/// A step-block profile whose trajectory stages an empty run: step 0
/// commits the high-confidence plateau (τ 0.5), steps 1–3 are
/// fallback-only in the non-eliding schedule (τ 0.995, accepts 1.0 < the
/// default elide floor), and step 4 drains the low band (τ 0.25). The
/// planner should jump 1–3 and land on 4.
fn elidable_profile(blocks: usize) -> Profile {
    Profile::step_block(
        vec![vec![0.5, 0.995, 0.995, 0.995, 0.25]; blocks],
        Metric::Q1,
    )
    .with_accepts(vec![vec![8.0, 1.0, 1.0, 1.0, 9.0]; blocks])
}

/// Same empty run, but the promised landing step cannot accept by rule
/// (τ 0.995 over a 0.30–0.45 low band): every jump is a misprediction.
fn lying_profile(blocks: usize) -> Profile {
    Profile::step_block(
        vec![vec![0.5, 0.995, 0.995, 0.995, 0.995]; blocks],
        Metric::Q1,
    )
    .with_accepts(vec![vec![8.0, 1.0, 1.0, 1.0, 9.0]; blocks])
}

fn osdt_policy(profile: &Profile, kappa: f64, eps: f64, elide: bool) -> Box<dyn Policy> {
    let p = Osdt::from_profile(profile.clone(), kappa, eps);
    if elide {
        Box::new(p.with_elision(DEFAULT_ELIDE_FLOOR))
    } else {
        Box::new(p)
    }
}

/// Drain a batch through the step scheduler; results in admission order.
fn run_batch(
    m: &SimModel,
    policies: Vec<Box<dyn Policy>>,
    layouts: Vec<Vec<u32>>,
    fused: bool,
) -> Vec<DecodeResult> {
    let mut sched: StepScheduler<'_, SimModel, Box<dyn Policy>> =
        StepScheduler::new(m, CacheConfig::block_boundary(), 4);
    sched.set_fusion(fused);
    for (i, (p, l)) in policies.into_iter().zip(layouts).enumerate() {
        sched.admit(i as u64, l, p).unwrap();
    }
    let mut results = sched.drain().unwrap();
    results.sort_by_key(|(id, _)| *id);
    results.into_iter().map(|(_, r)| r).collect()
}

/// The core bar: across policy parameters, seeds, batch sizes, and both
/// decision paths (fused/host), elision-on is token-identical to
/// elision-off and strictly cheaper in window passes, with zero
/// mispredictions — the trajectory's predictions hold on the plateau.
#[test]
fn prop_elision_is_token_identical_when_predictions_hold() {
    prop::forall(
        "elision-token-identity",
        24,
        |r: &mut Rng| {
            (
                r.next_u64(),
                1 + r.below(4) as usize,
                r.below(2) == 0,
                r.below(2) == 0,
            )
        },
        |&(seed, n, fused, tight)| {
            let m = SimModel::plateau_like(seed);
            let cfg = tiny_config();
            let profile = elidable_profile(cfg.num_blocks);
            // tight = the paper's exact-τ spec; loose exercises the κ/ε
            // clamp interacting with the landing-step threshold
            let (kappa, eps) = if tight { (1.0, 0.0) } else { (0.9, 0.1) };
            let layouts: Vec<Vec<u32>> = (0..n)
                .map(|i| m.layout_from_seed(seed ^ (i as u64)))
                .collect();
            let mk = |elide: bool| -> Vec<Box<dyn Policy>> {
                (0..n).map(|_| osdt_policy(&profile, kappa, eps, elide)).collect()
            };
            let off = run_batch(&m, mk(false), layouts.clone(), fused);
            let on = run_batch(&m, mk(true), layouts, fused);
            for (i, (a, b)) in on.iter().zip(&off).enumerate() {
                if a.tokens != b.tokens {
                    return Err(format!("seq {i}: tokens diverge under elision"));
                }
                if a.steps_elided == 0 {
                    return Err(format!("seq {i}: planner never elided"));
                }
                if a.elision_mispredictions != 0 {
                    return Err(format!(
                        "seq {i}: {} mispredictions on a faithful profile",
                        a.elision_mispredictions
                    ));
                }
                if a.window_passes >= b.window_passes {
                    return Err(format!(
                        "seq {i}: elision saved nothing ({} vs {} window passes)",
                        a.window_passes, b.window_passes
                    ));
                }
                if a.blocks_retired_early == 0 {
                    return Err(format!("seq {i}: no block retired early"));
                }
            }
            Ok(())
        },
    );
}

/// A lying trajectory: every jump lands on a step that falls back. The
/// decode must detect one misprediction per block, still complete, and —
/// because plateau confidence is position-pure — commit exactly the
/// non-eliding tokens (bounded divergence collapses to identity here).
#[test]
fn mispredicted_elision_is_detected_and_bounded() {
    let m = SimModel::plateau_like(77);
    let cfg = tiny_config();
    let lying = lying_profile(cfg.num_blocks);
    let layout = m.layout_from_seed(1);
    let off = run_batch(
        &m,
        vec![osdt_policy(&lying, 1.0, 0.0, false)],
        vec![layout.clone()],
        true,
    );
    let on = run_batch(
        &m,
        vec![osdt_policy(&lying, 1.0, 0.0, true)],
        vec![layout],
        true,
    );
    assert_eq!(on[0].tokens, off[0].tokens, "divergence must stay bounded");
    assert!(on[0].steps_elided > 0, "the lying profile must trigger jumps");
    assert_eq!(
        on[0].elision_mispredictions, cfg.num_blocks,
        "every block's jump lands on a fallback step"
    );
    // a mispredicted jump skips only fallback-singleton steps, so the
    // executed-step count cannot exceed the non-eliding schedule's
    assert!(
        on[0].steps <= off[0].steps,
        "misprediction must not add executed steps ({} vs {})",
        on[0].steps,
        off[0].steps
    );
}

/// Elided schedule steps never enter a window group: they occupy no bucket
/// slot, add no padding rows, and report no commits — padding accounting
/// stays a pure function of live rows (the §13/§14 invariant).
#[test]
fn elided_steps_are_not_padding_rows() {
    let m = SimModel::plateau_like(5);
    let cfg = tiny_config();
    let profile = elidable_profile(cfg.num_blocks);
    let mut sched: StepScheduler<'_, SimModel, Box<dyn Policy>> =
        StepScheduler::new(&m, CacheConfig::block_boundary(), 4);
    for i in 0..3u64 {
        sched
            .admit(
                i,
                m.layout_from_seed(10 + i),
                osdt_policy(&profile, 1.0, 0.0, true),
            )
            .unwrap();
    }
    // step 1: all three sequences run their block-boundary refresh
    let r0 = sched.step().unwrap();
    assert_eq!(r0.full_passes, 3);
    assert_eq!(r0.steps_elided, 0, "refresh steps never elide");
    // step 2: each sequence elides steps 1-3 and executes the landing step
    let r1 = sched.step().unwrap();
    assert_eq!(r1.steps_elided, 9, "3 sequences x 3 elided steps");
    assert_eq!(r1.window_passes, 3, "only the landing steps execute");
    assert_eq!(
        r1.window_groups,
        vec![(3, 4)],
        "one group of 3 live rows in the 4-bucket"
    );
    assert_eq!(
        r1.padding_rows, 1,
        "padding = bucket - live rows; elided steps contribute nothing"
    );
    assert_eq!(r1.accepted.len(), 3, "only live rows report commits");
    assert!(r1.accepted.iter().all(|&(_, n)| n > 0));
    assert_eq!(r1.elision_mispredictions, 0);
    assert_eq!(
        r1.blocks_retired_early, 3,
        "each block completed with elided steps"
    );
}

/// Drift signatures compare executed steps only: an eliding decode's trace
/// is shorter per block, the cosine's clamp-extension aligns it against a
/// full-schedule reference, and the registry must not read elision as
/// drift.
#[test]
fn eliding_decode_does_not_read_as_drift() {
    let m = SimModel::plateau_like(9);
    let cfg = tiny_config();
    let profile = elidable_profile(cfg.num_blocks);
    let layout = m.layout_from_seed(3);
    // host path keeps full per-step confidence vectors in both traces
    let off = run_batch(
        &m,
        vec![osdt_policy(&profile, 1.0, 0.0, false)],
        vec![layout.clone()],
        false,
    );
    let on = run_batch(
        &m,
        vec![osdt_policy(&profile, 1.0, 0.0, true)],
        vec![layout],
        false,
    );
    let (off, on) = (&off[0], &on[0]);
    let mut on_total = 0usize;
    let mut off_total = 0usize;
    for b in 0..cfg.num_blocks {
        let (e, f) = (on.trace.steps_recorded(b), off.trace.steps_recorded(b));
        assert!(
            e <= f,
            "block {b}: eliding trace holds {e} steps vs {f} executed-only"
        );
        assert!(e >= 1, "block {b}: at least the refresh step is recorded");
        on_total += e;
        off_total += f;
    }
    assert!(
        on_total < off_total,
        "elision must shorten the executed-step trace ({on_total} vs {off_total})"
    );
    let cos = signature_cosine(
        &off.trace.block_signatures(),
        &on.trace.block_signatures(),
    )
    .expect("both traces are non-empty");
    assert!(
        cos > 0.95,
        "clamp-extended alignment must not read elision as drift (cosine {cos})"
    );
    // registry-level: adopt a full-schedule drift reference, then observe
    // the eliding decode — the profile must stay fresh
    let reg = ProfileRegistry::in_memory();
    let key = ProfileKey::new("synth-plateau", DynamicMode::StepBlock, Metric::Q1);
    match reg.acquire(&key) {
        Acquired::Lease(l) => l.fulfill(profile, off.trace.signature()),
        _ => panic!("first acquire must lease"),
    }
    reg.observe(&key, 1, &off.trace); // becomes the drift reference
    reg.observe(&key, 1, &on.trace);
    assert!(
        !reg.get(&key).unwrap().stale,
        "an eliding decode observed against a full-schedule reference \
         must not mark the profile stale"
    );
}

/// End-to-end misprediction storm through the serving stack: a seeded
/// lying profile mispredicts on every block, the coordinator feeds the
/// mispredictions to the registry, the profile goes stale, the next
/// request recalibrates, and service continues — requests complete
/// throughout (§9 drift loop, elision-triggered).
#[test]
fn misprediction_storm_recalibrates_through_the_coordinator() {
    let registry = Arc::new(ProfileRegistry::with_config(RegistryConfig {
        misprediction_floor: 2,
        ..RegistryConfig::default()
    }));
    let key = ProfileKey::new("synth-math", DynamicMode::StepBlock, Metric::Q1);
    match registry.acquire(&key) {
        Acquired::Lease(l) => {
            l.fulfill(lying_profile(tiny_config().num_blocks), vec![0.5; 4])
        }
        _ => panic!("seeding acquire must lease"),
    }
    let coord = Coordinator::start_with_registry(
        CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_wait: Duration::from_millis(5),
            cache: CacheConfig::block_boundary(),
            step_elision: true,
            ..CoordinatorConfig::default()
        },
        tiny_config(),
        registry.clone(),
        |_| Ok(SimModel::plateau_like(42)),
    )
    .unwrap();
    // decode under the seeded lying profile: completes despite the storm
    let r1 = coord.generate("synth-math", "Q: 1+1=?", SPEC).unwrap();
    assert!(r1.error.is_none(), "{:?}", r1.error);
    assert!(!r1.calibrated, "the seeded profile serves the first request");
    assert!(coord.metrics.counter_value("steps_elided") > 0);
    assert!(
        coord.metrics.counter_value("elision_mispredictions") >= 2,
        "the lying profile must mispredict past the floor"
    );
    assert!(
        registry.get(&key).unwrap().stale,
        "the misprediction storm must mark the profile stale"
    );
    assert!(registry.metrics().counter_value("drift_events") >= 1);
    // the scheduled recalibration fires on the next request...
    let r2 = coord.generate("synth-math", "Q: 2+2=?", SPEC).unwrap();
    assert!(r2.error.is_none(), "{:?}", r2.error);
    assert!(r2.calibrated, "stale profile must trigger recalibration");
    // ...and service continues from the fresh profile
    let r3 = coord.generate("synth-math", "Q: 3+3=?", SPEC).unwrap();
    assert!(r3.error.is_none(), "{:?}", r3.error);
    assert!(!r3.calibrated);
    assert!(!registry.get(&key).unwrap().stale);
    coord.shutdown();
}

/// With elision disabled (the default), the planner is never attached:
/// the same profile decodes the full schedule and no elision counter
/// moves — protecting every pre-elision caller.
#[test]
fn elision_off_is_the_status_quo() {
    let registry = Arc::new(ProfileRegistry::in_memory());
    let key = ProfileKey::new("synth-math", DynamicMode::StepBlock, Metric::Q1);
    match registry.acquire(&key) {
        Acquired::Lease(l) => {
            l.fulfill(elidable_profile(tiny_config().num_blocks), vec![0.5; 4])
        }
        _ => panic!(),
    }
    let coord = Coordinator::start_with_registry(
        CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_wait: Duration::from_millis(5),
            cache: CacheConfig::block_boundary(),
            ..CoordinatorConfig::default()
        },
        tiny_config(),
        registry,
        |_| Ok(SimModel::plateau_like(42)),
    )
    .unwrap();
    let r = coord.generate("synth-math", "Q: 1+1=?", SPEC).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(coord.metrics.counter_value("steps_elided"), 0);
    assert_eq!(coord.metrics.counter_value("elision_mispredictions"), 0);
    assert_eq!(coord.metrics.counter_value("blocks_retired_early"), 0);
    coord.shutdown();
}
