//! Integration tests of the fleet-wide ProfileRegistry (DESIGN.md §9):
//! single-flight calibration across replicas, signature-drift
//! recalibration, and warm-start persistence — all over the analytic
//! simulator, artifact-free.

use std::sync::Arc;
use std::time::Duration;

use osdt::cache::CacheConfig;
use osdt::coordinator::router::{Router, RoutingPolicy};
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::decode::Engine;
use osdt::model::fixtures::tiny_config;
use osdt::policy::{
    Calibrator, DynamicMode, Metric, Osdt, ProfileKey, ProfileRegistry,
    ProfileStore, RegistryConfig, StaticThreshold,
};
use osdt::sim::SimModel;
use osdt::tokenizer::Tokenizer;

const SPEC: &str = "osdt:block:q1:0.75:0.2";
const KAPPA: f64 = 0.75;
const EPSILON: f64 = 0.2;

fn key() -> ProfileKey {
    ProfileKey::new("synth-math", DynamicMode::Block, Metric::Q1)
}

fn replica(registry: &Arc<ProfileRegistry>, workers: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start_with_registry(
            CoordinatorConfig {
                workers,
                max_batch: 4,
                batch_wait: Duration::from_millis(5),
                cache: CacheConfig::disabled(),
                ..CoordinatorConfig::default()
            },
            tiny_config(),
            registry.clone(),
            |_| Ok(SimModel::math_like(5)),
        )
        .unwrap(),
    )
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "osdt_registry_it_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// N replicas × M concurrent same-task OSDT requests -> exactly one
/// calibration fleet-wide, and every response token-identical to the
/// pre-refactor single-replica path (calibration decode for the winner,
/// profile decode for everyone else).
#[test]
fn fleet_calibrates_once_with_token_identical_outputs() {
    let prompt = "Q: 2+2=?";

    // pre-refactor reference: solo engine, Phase 1 then Phase 2 on the
    // same prompt
    let m = SimModel::math_like(5);
    let cfg = tiny_config();
    let tok = Tokenizer::from_config(&cfg).unwrap();
    let engine = Engine::new(&m);
    let layout = tok.layout_prompt(&cfg, prompt).unwrap();
    let cal_ref = engine
        .decode(layout.clone(), &StaticThreshold::new(0.9))
        .unwrap();
    let cal_completion = tok.decode_until_eos(cal_ref.gen_tokens(&cfg));
    let profile = Calibrator::calibrate(&cal_ref.trace, DynamicMode::Block, Metric::Q1);
    let osdt_ref = engine
        .decode(layout, &Osdt::from_profile(profile, KAPPA, EPSILON))
        .unwrap();
    let osdt_completion = tok.decode_until_eos(osdt_ref.gen_tokens(&cfg));

    // fleet: 3 replicas × 2 workers sharing one registry, least-loaded
    // routing (placement deliberately profile-oblivious)
    let registry = Arc::new(ProfileRegistry::in_memory());
    let replicas = vec![
        replica(&registry, 2),
        replica(&registry, 2),
        replica(&registry, 2),
    ];
    let coords: Vec<Arc<Coordinator>> = replicas.clone();
    let router = Router::new(replicas, RoutingPolicy::LeastOutstanding).unwrap();
    let pending: Vec<_> = (0..18)
        .map(|_| {
            router.submit(Request {
                id: 0,
                task: "synth-math".into(),
                prompt: prompt.into(),
                policy: SPEC.into(),
                slo_ms: None,
            })
        })
        .collect();
    let mut calibrated = 0usize;
    for p in pending {
        let resp = p.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        if resp.calibrated {
            calibrated += 1;
            assert_eq!(
                resp.completion, cal_completion,
                "calibration decode diverged from the pre-refactor path"
            );
            assert_eq!(resp.steps, cal_ref.steps);
        } else {
            assert_eq!(
                resp.completion, osdt_completion,
                "profile decode diverged from the pre-refactor path"
            );
            assert_eq!(resp.steps, osdt_ref.steps);
        }
    }
    assert_eq!(calibrated, 1, "exactly one calibration fleet-wide");
    let fleet: u64 = coords
        .iter()
        .map(|c| c.metrics.counter_value("calibrations"))
        .sum();
    assert_eq!(fleet, 1);
    assert_eq!(registry.metrics().counter_value("calibrations_completed"), 1);
    assert_eq!(registry.len(), 1);
}

/// Injected signature drift marks the profile stale; the next request runs
/// a recalibration (counted as such) and service continues.
#[test]
fn drift_injection_triggers_recalibration() {
    let registry = Arc::new(ProfileRegistry::with_config(RegistryConfig {
        drift_floor: 0.95,
        ema_alpha: 0.0,
        ..RegistryConfig::default()
    }));
    let coord = replica(&registry, 1);
    // calibrate + one normal decode (adopts the drift reference)
    assert!(coord.generate("synth-math", "Q: 1+2=?", SPEC).unwrap().calibrated);
    assert!(!coord.generate("synth-math", "Q: 3+4=?", SPEC).unwrap().calibrated);

    // inject a decode whose signature shape diverges from the reference
    let mut divergent = osdt::policy::CalibrationTrace::new(tiny_config().num_blocks);
    for b in 0..tiny_config().num_blocks {
        divergent.record(b, 0, &[0.95, 0.02]);
        divergent.record(b, 1, &[0.01]);
    }
    let epoch = registry.get(&key()).unwrap().epoch;
    registry.observe(&key(), epoch, &divergent);
    assert!(
        registry.get(&key()).unwrap().stale,
        "divergent signature must mark the profile stale"
    );
    assert_eq!(registry.metrics().counter_value("drift_events"), 1);

    // next request recalibrates; the one after reuses the fresh profile
    assert!(coord.generate("synth-math", "Q: 5+6=?", SPEC).unwrap().calibrated);
    assert!(!coord.generate("synth-math", "Q: 7+8=?", SPEC).unwrap().calibrated);
    assert_eq!(registry.metrics().counter_value("recalibrations"), 1);
    let entry = registry.get(&key()).unwrap();
    assert!(!entry.stale);
    assert_eq!(entry.version, 2);
}

/// A restarted coordinator warm-starts from disk: the second process
/// serves OSDT with zero calibrations.
#[test]
fn restart_warm_starts_from_disk_with_zero_calibrations() {
    let dir = tmp_dir("warm");
    let completion_a;
    {
        let registry = Arc::new(
            ProfileRegistry::with_store(
                ProfileStore::new(&dir).unwrap(),
                RegistryConfig::default(),
            )
            .unwrap(),
        );
        let coord = replica(&registry, 1);
        let r = coord.generate("synth-math", "Q: 2+3=?", SPEC).unwrap();
        assert!(r.calibrated, "cold store must calibrate");
        completion_a = coord
            .generate("synth-math", "Q: 2+3=?", SPEC)
            .unwrap()
            .completion;
    } // coordinator + registry dropped: the "restart"

    let registry = Arc::new(
        ProfileRegistry::with_store(
            ProfileStore::new(&dir).unwrap(),
            RegistryConfig::default(),
        )
        .unwrap(),
    );
    assert_eq!(registry.len(), 1, "profile must reload from disk");
    let coord = replica(&registry, 1);
    let r = coord.generate("synth-math", "Q: 2+3=?", SPEC).unwrap();
    assert!(
        !r.calibrated,
        "warm-started coordinator must not recalibrate"
    );
    assert_eq!(r.completion, completion_a, "reloaded profile must decode identically");
    assert_eq!(registry.metrics().counter_value("calibrations_completed"), 0);
    assert_eq!(registry.metrics().counter_value("profile_warm_starts"), 1);
    assert!(registry.get(&key()).unwrap().warm_started);
    std::fs::remove_dir_all(&dir).ok();
}

/// Different (mode, metric) combinations are independent keys: each
/// calibrates once, and the admin snapshot lists them all.
#[test]
fn distinct_modes_and_metrics_calibrate_independently() {
    let registry = Arc::new(ProfileRegistry::in_memory());
    let coord = replica(&registry, 1);
    for spec in [
        "osdt:block:q1:0.75:0.2",
        "osdt:block:q2:0.75:0.2",
        "osdt:step-block:q1:0.75:0.2",
    ] {
        assert!(coord.generate("synth-math", "Q: 1+1=?", spec).unwrap().calibrated);
        assert!(!coord.generate("synth-math", "Q: 1+1=?", spec).unwrap().calibrated);
    }
    assert_eq!(registry.len(), 3);
    assert_eq!(registry.metrics().counter_value("calibrations_completed"), 3);
    let snap = registry.snapshot();
    assert_eq!(snap.len(), 3);
    assert!(snap.iter().all(|s| s.key.task == "synth-math"));
}
