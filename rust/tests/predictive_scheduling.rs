//! Integration tests for signature-driven predictive scheduling
//! (DESIGN.md §15): admission order is pure scheduling — it moves waiting,
//! never tokens — the aged shortest-predicted-job-first queue stays live
//! under a flood of cheap jobs, and the cost model's elision-aware
//! forecasts never exceed the naive schedule depth.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use osdt::cache::CacheConfig;
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::decode::CostModel;
use osdt::model::fixtures::tiny_config;
use osdt::policy::{
    Acquired, DynamicMode, Metric, Profile, ProfileKey, ProfileRegistry,
};
use osdt::sim::SimModel;
use osdt::util::prop;
use osdt::util::rng::Rng;

const POLICY: &str = "osdt:step-block:q1:1:0";

/// Step-block profile whose per-block schedule is `depth` steps: a
/// committing first step, `depth - 2` near-empty middle steps, and a cheap
/// landing step that drains the block. On the plateau simulator the
/// forecast for this trajectory is `depth` window passes per block.
fn profile_with_depth(depth: usize) -> Profile {
    assert!(depth >= 2);
    let mut taus = vec![0.5];
    taus.extend(std::iter::repeat(0.995).take(depth - 2));
    taus.push(0.25);
    let mut accepts = vec![8.0];
    // accepts 2.0 sit above the default elide floor: the schedule keeps
    // its full depth even on elision-enabled configurations
    accepts.extend(std::iter::repeat(2.0).take(depth - 2));
    accepts.push(9.0);
    let blocks = tiny_config().num_blocks;
    Profile::step_block(vec![taus; blocks], Metric::Q1)
        .with_accepts(vec![accepts; blocks])
}

/// Registry pre-seeded with a cheap "synth-short" and an expensive
/// "synth-long" trajectory, so every request decodes (and is forecast)
/// from a real profile with no calibration in the test body.
fn seeded_registry() -> Arc<ProfileRegistry> {
    let registry = Arc::new(ProfileRegistry::in_memory());
    for (task, depth) in [("synth-short", 5), ("synth-long", 25)] {
        match registry.acquire(&ProfileKey::new(
            task,
            DynamicMode::StepBlock,
            Metric::Q1,
        )) {
            Acquired::Lease(lease) => {
                lease.fulfill(profile_with_depth(depth), vec![0.5; 4])
            }
            _ => panic!("seeding the {task} profile must grant the lease"),
        }
    }
    registry
}

fn start(
    predictive: bool,
    align_band: usize,
    max_batch: usize,
) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start_with_registry(
            CoordinatorConfig {
                workers: 1,
                max_batch,
                batch_wait: Duration::from_millis(5),
                cache: CacheConfig::block_boundary(),
                predictive,
                align_band,
                ..CoordinatorConfig::default()
            },
            tiny_config(),
            seeded_registry(),
            |_| Ok(SimModel::plateau_like(7)),
        )
        .unwrap(),
    )
}

fn request(i: usize) -> Request {
    // every third request is expensive — the mixed-length workload whose
    // ordering the admission policy is free to change
    let task = if i % 3 == 0 { "synth-long" } else { "synth-short" };
    Request {
        id: 0,
        task: task.into(),
        prompt: format!("Q: {i}+1=?"),
        policy: POLICY.into(),
        slo_ms: None,
    }
}

/// Scheduling is invisible in the output: FIFO, predicted-cost, and
/// predicted-cost-plus-alignment admission must produce bit-identical
/// completions and execute exactly the same forward passes for the same
/// request set.
#[test]
fn admission_order_never_changes_tokens_or_passes() {
    let mut arms = Vec::new();
    for (label, predictive, band) in
        [("fifo", false, 0), ("predictive", true, 0), ("aligned", true, 8)]
    {
        let coord = start(predictive, band, 2);
        let rxs: Vec<_> =
            (0..12).map(|i| coord.submit(request(i))).collect();
        let completions: Vec<String> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv().unwrap();
                assert!(r.error.is_none(), "{label}: {:?}", r.error);
                r.completion
            })
            .collect();
        let passes = coord.metrics.counter_value("window_passes")
            + coord.metrics.counter_value("full_passes");
        arms.push((label, completions, passes));
    }
    for w in arms.windows(2) {
        assert_eq!(
            w[0].1, w[1].1,
            "completions diverge between {} and {}",
            w[0].0, w[1].0
        );
        assert_eq!(
            w[0].2, w[1].2,
            "executed passes diverge between {} and {}",
            w[0].0, w[1].0
        );
    }
}

/// Aged SPJF liveness: an expensive job queued behind a continuing flood
/// of cheap jobs still completes — wait-time aging bounds how long a
/// cheaper newcomer can keep overtaking it (DESIGN.md §15).
#[test]
fn cheap_flood_cannot_starve_an_expensive_job() {
    let coord = start(true, 0, 1);
    // a first wave of cheap jobs builds the backlog the long job queues
    // behind
    let mut floods: Vec<_> = (0..8)
        .map(|i| {
            coord.submit(Request {
                id: 0,
                task: "synth-short".into(),
                prompt: format!("Q: {i}+2=?"),
                policy: POLICY.into(),
                slo_ms: None,
            })
        })
        .collect();
    let long_rx = coord.submit(Request {
        id: 0,
        task: "synth-long".into(),
        prompt: "Q: 9+9=?".into(),
        policy: POLICY.into(),
        slo_ms: None,
    });
    // adversarial arrivals: keep feeding fresh cheap jobs (each of which
    // out-scores the long job until aging catches up) while it waits
    let stop = Arc::new(AtomicBool::new(false));
    let producer = {
        let coord = coord.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut rxs = Vec::new();
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                rxs.push(coord.submit(Request {
                    id: 0,
                    task: "synth-short".into(),
                    prompt: format!("Q: {i}+3=?"),
                    policy: POLICY.into(),
                    slo_ms: None,
                }));
                i += 1;
                std::thread::sleep(Duration::from_micros(300));
            }
            rxs
        })
    };
    let long = long_rx
        .recv_timeout(Duration::from_secs(20))
        .expect("expensive job starved by the cheap-job flood");
    assert!(long.error.is_none(), "{:?}", long.error);
    stop.store(true, Ordering::Relaxed);
    let flood_rxs = producer.join().unwrap();
    floods.extend(flood_rxs);
    for rx in floods {
        let r = rx.recv_timeout(Duration::from_secs(20)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
}

/// The elision-aware forecast can only remove passes from the naive
/// schedule: for random acceptance trajectories, a cost model with an
/// elide floor never predicts more total passes than one without.
#[test]
fn prop_elision_aware_forecast_never_exceeds_naive() {
    let cfg = tiny_config();
    prop::forall(
        "elision-forecast-bounded",
        80,
        |r: &mut Rng| {
            let depth = 2 + r.below(10) as usize;
            let floor = 0.5 + r.next_f64() * 2.0;
            let seed = r.next_u64();
            (depth, floor, seed)
        },
        |&(depth, floor, seed)| {
            let mut rng = Rng::new(seed);
            let blocks = cfg.num_blocks;
            let taus = vec![vec![0.9; depth]; blocks];
            let accepts: Vec<Vec<f64>> = (0..blocks)
                .map(|_| {
                    (0..depth).map(|_| rng.next_f64() * 8.0).collect()
                })
                .collect();
            let profile = Profile::step_block(taus, Metric::Q1)
                .with_accepts(accepts);
            let naive =
                CostModel::new(None).forecast(Some(&profile), &cfg);
            let elided = CostModel::new(Some(floor))
                .forecast(Some(&profile), &cfg);
            if !naive.calibrated || !elided.calibrated {
                return Err("seeded profile must yield a calibrated forecast".into());
            }
            if elided.total_passes > naive.total_passes {
                return Err(format!(
                    "elision-aware forecast {} > naive {} (depth {depth}, floor {floor:.2})",
                    elided.total_passes, naive.total_passes
                ));
            }
            // both must still pay the per-block full passes
            if elided.total_passes < blocks {
                return Err("forecast lost the full-pass floor".into());
            }
            Ok(())
        },
    );
}
