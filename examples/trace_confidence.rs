//! Confidence-signature explorer: reproduces the paper's two observations
//! (Figures 1–2) interactively on the real model — U-shaped step-block mean
//! confidence and near-1 pairwise cosine similarity across inputs — and
//! prints the calibrated thresholds each (mode, metric) pair would derive.
//!
//!     cargo run --release --example trace_confidence -- [task] [n]
//!     (defaults: synth-math 6)

use anyhow::Result;

use osdt::bench;
use osdt::model::ModelConfig;
use osdt::policy::{Calibrator, DynamicMode, Metric};
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::workload::Dataset;

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let task = args.first().map(String::as_str).unwrap_or("synth-math");
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), task)?;

    let traces = bench::collect_traces(&rt, &tok, &ds, n, bench::CALIBRATION_TAU)?;

    // Figure 1: step-block mean confidence trajectory
    let sig = bench::mean_signature(&traces);
    print!(
        "{}",
        bench::ascii_plot(
            &sig,
            14,
            &format!("{task}: step-block mean confidence ({n} inputs averaged)")
        )
    );

    // Figure 2: pairwise cosine similarity
    let m = bench::cosine_matrix(&traces);
    let mut lo = f64::INFINITY;
    let mut sum = 0.0;
    let mut cnt = 0.0;
    for i in 0..m.len() {
        for j in 0..m.len() {
            if i != j {
                lo = lo.min(m[i][j]);
                sum += m[i][j];
                cnt += 1.0;
            }
        }
    }
    print!(
        "{}",
        bench::ascii_heatmap(&m, 0.9, 1.0, &format!("{task}: pairwise cosine"))
    );
    println!("off-diagonal cosine: mean {:.4}, min {:.4}\n", sum / cnt, lo);

    // What each calibration (mode, metric) derives from trace #0
    println!("calibrated thresholds from input 0:");
    for metric in [Metric::Mean, Metric::Q1, Metric::Median, Metric::Q3] {
        let p = Calibrator::calibrate(&traces[0], DynamicMode::Block, metric);
        let taus: Vec<String> = (0..cfg.num_blocks)
            .map(|b| format!("{:.3}", p.tau(b, 0)))
            .collect();
        println!("  block mode, {:<12} tau = [{}]", metric.as_str(), taus.join(", "));
    }
    let p = Calibrator::calibrate(&traces[0], DynamicMode::StepBlock, Metric::Median);
    println!(
        "  step-block q2, block 0 first steps: {:?}",
        (0..traces[0].per_block[0].len().min(6))
            .map(|s| (p.tau(0, s) * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    Ok(())
}
