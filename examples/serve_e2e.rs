//! End-to-end serving driver (the repository's headline validation run):
//! starts the coordinator + TCP server on the real model, replays a
//! Poisson-arrival multi-task trace through real sockets with several
//! client threads, and reports latency percentiles + throughput per policy.
//!
//!     cargo run --release --example serve_e2e -- [n_requests] [rate_rps]
//!     (defaults: 36 requests at 4 rps)
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use osdt::coordinator::{Coordinator, CoordinatorConfig};
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::server::{Client, Server};
use osdt::util::stats::Histogram;
use osdt::workload::{mixed_trace, Dataset};

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(36);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4.0);

    // ---- stack: coordinator (2 workers, batching) + TCP server ------------
    let cfg = ModelConfig::load("artifacts")?;
    let ccfg = CoordinatorConfig {
        workers: 2,
        max_batch: 4,
        batch_wait: Duration::from_millis(4),
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start(ccfg, cfg.clone(), |wid| {
        log::info!("worker {wid}: loading PJRT runtime");
        let cfg = ModelConfig::load("artifacts")?;
        ModelRuntime::load(&cfg)
    })?);
    let server = Server::start("127.0.0.1:0", coord.clone())?;
    let addr = server.addr;
    // the same registries the workers mutate, exposed as Prometheus text
    let metrics = osdt::metrics::http::MetricsServer::start(
        "127.0.0.1:0",
        vec![coord.metrics.clone(), coord.registry.metrics().clone()],
    )?;
    println!("serving on {addr} (2 workers, max batch 4)");
    println!("metrics on http://{}/metrics", metrics.addr);

    // ---- workload: Poisson mixture over the three tasks --------------------
    let datasets = Dataset::load_all(cfg.artifact_dir.join("data"))?;
    let trace = mixed_trace(&datasets, rate, n, 42);
    let policy = "osdt:block:q1:0.75:0.2";
    println!("replaying {n} requests at ~{rate} rps, policy {policy}");

    let lat = Arc::new(Mutex::new(Histogram::latency()));
    let ok = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    // 4 client connections round-robin the trace, honoring arrival times
    for c in 0..4usize {
        let reqs: Vec<_> = trace
            .iter()
            .enumerate()
            .filter(|(i, _)| i % 4 == c)
            .map(|(_, r)| r.clone())
            .collect();
        let lat = lat.clone();
        let ok = ok.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut client = Client::connect(addr)?;
            for r in reqs {
                let due = Duration::from_secs_f64(r.at);
                if let Some(wait) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(wait);
                }
                let sent = Instant::now();
                let resp = client.generate(&r.task, &r.prompt, policy)?;
                let e2e_us = sent.elapsed().as_secs_f64() * 1e6;
                lat.lock().unwrap().record(e2e_us);
                if resp.error.is_none() {
                    ok.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client thread")?;
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- report -------------------------------------------------------------
    let lat = lat.lock().unwrap();
    let done = ok.load(std::sync::atomic::Ordering::Relaxed);
    println!("\n== end-to-end serving report ==");
    println!("requests          : {done}/{n} ok in {wall:.2}s");
    println!("request rate      : {:.2} rps (offered ~{rate})", n as f64 / wall);
    println!(
        "gen throughput    : {:.1} tokens/s",
        (done as usize * cfg.gen_len) as f64 / wall
    );
    println!(
        "latency e2e       : p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms max {:.0}ms",
        lat.quantile(0.5) / 1e3,
        lat.quantile(0.95) / 1e3,
        lat.quantile(0.99) / 1e3,
        lat.max / 1e3
    );
    let mut mc = Client::connect(addr)?;
    println!("\n== server metrics ==\n{}", mc.metrics()?);

    // ---- Prometheus endpoint: scrape it the way a collector would ----------
    {
        use std::io::{Read as _, Write as _};
        let mut s = std::net::TcpStream::connect(metrics.addr)?;
        write!(s, "GET /metrics HTTP/1.1\r\nHost: e2e\r\n\r\n")?;
        let mut buf = String::new();
        s.read_to_string(&mut buf)?;
        let (head, body) = buf.split_once("\r\n\r\n").unwrap_or((buf.as_str(), ""));
        let status = head.lines().next().unwrap_or("");
        println!("\n== GET /metrics -> {status} ==");
        // print the request-lifecycle families; the full exposition is long
        for line in body.lines().filter(|l| {
            l.contains("osdt_requests_")
                || l.contains("osdt_request_latency_seconds_sum")
                || l.contains("osdt_request_ttft_seconds_sum")
                || l.contains("osdt_calibrations_completed_total")
        }) {
            println!("{line}");
        }
        println!("({} exposition lines total)", body.lines().count());
    }
    metrics.stop();
    server.stop();
    match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        Err(_) => {}
    }
    Ok(())
}
