//! Quickstart: load the AOT artifacts, decode a few prompts under different
//! threshold policies, and print completions + step counts.
//!
//!     cargo run --release --example quickstart
//!
//! Shows the core trade-off the paper studies: sequential decoding spends
//! one forward pass per token; threshold policies commit many tokens per
//! pass at some accuracy risk.

use anyhow::Result;

use osdt::decode::Engine;
use osdt::model::ModelConfig;
use osdt::policy::{FactorThreshold, Policy, SequentialTopK, StaticThreshold};
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;

fn main() -> Result<()> {
    osdt::util::logging::init();
    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;
    let engine = Engine::new(&rt);

    let prompts = [
        "Q: 3+4-2=?",
        "Q: class of bab? (A) rok (B) lum (C) dax (D) fen",
        "op: rev | in: abc",
    ];
    let policies: Vec<(&str, Box<dyn Policy>)> = vec![
        ("sequential (LLaDA)", Box::new(SequentialTopK::new(1))),
        ("static τ=0.9 (Fast-dLLM)", Box::new(StaticThreshold::new(0.9))),
        ("factor 0.95 (Fast-dLLM)", Box::new(FactorThreshold::new(0.95))),
    ];

    for prompt in prompts {
        println!("\n=== {prompt}");
        for (name, policy) in &policies {
            let layout = tok.layout_prompt(&cfg, prompt)?;
            let t0 = std::time::Instant::now();
            let res = engine.decode(layout, policy.as_ref())?;
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "  {name:<26} steps={:<3} tokens/s={:<7.1} -> {}",
                res.steps,
                cfg.gen_len as f64 / dt,
                tok.decode_until_eos(res.gen_tokens(&cfg)),
            );
        }
    }
    println!(
        "\n(OSDT itself needs a one-shot calibration pass — see \
         examples/calibrate_eval.rs and the `osdt eval` subcommand.)"
    );
    Ok(())
}
