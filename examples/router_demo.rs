//! Multi-replica routing demo: two coordinator replicas (each with its own
//! PJRT runtime), fronted by the task-affinity router. Shows the OSDT-aware
//! placement property: each task calibrates exactly once across the fleet,
//! and subsequent requests reuse the home replica's profile.
//!
//!     cargo run --release --example router_demo -- [n_per_task]

use std::sync::Arc;

use anyhow::Result;

use osdt::coordinator::router::{Router, RoutingPolicy};
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::workload::{Dataset, TASKS};

fn main() -> Result<()> {
    osdt::util::logging::init();
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);

    let cfg = ModelConfig::load("artifacts")?;
    let mk_replica = || -> Result<Arc<Coordinator>> {
        Ok(Arc::new(Coordinator::start(
            CoordinatorConfig::default(),
            cfg.clone(),
            |_| {
                let cfg = ModelConfig::load("artifacts")?;
                ModelRuntime::load(&cfg)
            },
        )?))
    };
    let replicas = vec![mk_replica()?, mk_replica()?];
    let coords: Vec<Arc<Coordinator>> = replicas.clone();
    let router = Router::new(replicas, RoutingPolicy::TaskAffinity { spill_margin: 4 })?;
    println!("router: 2 replicas, task-affinity placement");

    let datasets = Dataset::load_all(cfg.artifact_dir.join("data"))?;
    let policy = "osdt:block:q1:0.75:0.2";
    let mut calibrations = 0usize;
    for ds in &datasets {
        for ex in ds.examples.iter().take(n) {
            let resp = router
                .submit(Request {
                    id: 0,
                    task: ds.task.clone(),
                    prompt: ex.prompt.clone(),
                    policy: policy.into(),
                })
                .recv()?;
            if resp.calibrated {
                calibrations += 1;
                println!("  {}: calibrated on replica (one-shot)", ds.task);
            }
        }
    }
    println!("\nrouted totals per replica: {:?}", router.routed_counts());
    println!(
        "calibrations across fleet: {calibrations} (= {} tasks, one each)",
        TASKS.len()
    );
    let fleet_calibrations: u64 = coords
        .iter()
        .map(|c| c.metrics.counter_value("calibrations"))
        .sum();
    assert_eq!(fleet_calibrations as usize, calibrations);
    let completed: u64 = coords
        .iter()
        .map(|c| c.metrics.counter_value("requests_completed"))
        .sum();
    println!("requests completed: {completed}");
    Ok(())
}
