//! Multi-replica routing demo: two coordinator replicas (each with its own
//! PJRT runtime) sharing one fleet-wide ProfileRegistry, fronted by the
//! task-affinity router. Each task calibrates exactly once across the
//! fleet — enforced by the registry's single-flight calibration lease, not
//! by placement — while task affinity keeps each task's requests on a warm
//! home replica.
//!
//!     cargo run --release --example router_demo -- [n_per_task]

use std::sync::Arc;

use anyhow::Result;

use osdt::coordinator::router::{Router, RoutingPolicy};
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::model::ModelConfig;
use osdt::policy::ProfileRegistry;
use osdt::runtime::ModelRuntime;
use osdt::workload::{Dataset, TASKS};

fn main() -> Result<()> {
    osdt::util::logging::init();
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);

    let cfg = ModelConfig::load("artifacts")?;
    let registry = Arc::new(ProfileRegistry::in_memory());
    let mk_replica = || -> Result<Arc<Coordinator>> {
        Ok(Arc::new(Coordinator::start_with_registry(
            CoordinatorConfig::default(),
            cfg.clone(),
            registry.clone(),
            |_| {
                let cfg = ModelConfig::load("artifacts")?;
                ModelRuntime::load(&cfg)
            },
        )?))
    };
    let replicas = vec![mk_replica()?, mk_replica()?];
    let coords: Vec<Arc<Coordinator>> = replicas.clone();
    let router = Router::new(replicas, RoutingPolicy::TaskAffinity { spill_margin: 4 })?;
    println!("router: 2 replicas, shared profile registry, task-affinity placement");

    let datasets = Dataset::load_all(cfg.artifact_dir.join("data"))?;
    let policy = "osdt:block:q1:0.75:0.2";
    let mut calibrations = 0usize;
    for ds in &datasets {
        for ex in ds.examples.iter().take(n) {
            let resp = router
                .submit(Request {
                    id: 0,
                    task: ds.task.clone(),
                    prompt: ex.prompt.clone(),
                    policy: policy.into(),
                })
                .recv()?;
            if resp.calibrated {
                calibrations += 1;
                println!("  {}: calibrated on replica (one-shot)", ds.task);
            }
        }
    }
    println!("\nrouted totals per replica: {:?}", router.routed_counts());
    println!(
        "calibrations across fleet: {calibrations} (= {} tasks, one each)",
        TASKS.len()
    );
    let fleet_calibrations: u64 = coords
        .iter()
        .map(|c| c.metrics.counter_value("calibrations"))
        .sum();
    assert_eq!(fleet_calibrations as usize, calibrations);
    assert_eq!(
        registry.metrics().counter_value("calibrations_completed"),
        fleet_calibrations
    );
    println!(
        "registry: {} profiles, {} lease(s) granted",
        registry.len(),
        registry.metrics().counter_value("leases_granted")
    );
    let completed: u64 = coords
        .iter()
        .map(|c| c.metrics.counter_value("requests_completed"))
        .sum();
    println!("requests completed: {completed}");
    Ok(())
}
