//! OSDT end to end on one task: Phase-1 calibration on the first sequence,
//! profile persistence, Phase-2 evaluation, and the comparison against the
//! Fast-dLLM baselines — a miniature of Table 1 for a single task.
//!
//!     cargo run --release --example calibrate_eval -- [task] [n]
//!     (defaults: synth-math 48)

use anyhow::Result;

use osdt::bench::{self, RunOpts};
use osdt::decode::Engine;
use osdt::model::ModelConfig;
use osdt::policy::{
    Calibrator, DynamicMode, Metric, ProfileRecord, ProfileStore, StaticThreshold,
};
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::workload::Dataset;

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let task = args.first().map(String::as_str).unwrap_or("synth-math");
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), task)?;

    // ---- Phase 1: one-shot calibration (Algorithm 1, lines 3-6) -----------
    let engine = Engine::new(&rt);
    let layout = tok.layout_prompt(&cfg, &ds.examples[0].prompt)?;
    let cal = engine.decode(layout, &StaticThreshold::new(bench::CALIBRATION_TAU))?;
    println!(
        "calibration sequence: {} steps, signature length {}",
        cal.steps,
        cal.trace.signature().len()
    );
    let profile = Calibrator::calibrate(&cal.trace, DynamicMode::Block, Metric::Q1);
    let store = ProfileStore::new("profiles")?;
    let path = store.save(&ProfileRecord::new(task, profile, cal.trace.signature()))?;
    println!("profile saved -> {}", path.display());

    // ---- Phase 2: evaluate OSDT vs baselines --------------------------------
    let opts = RunOpts { n, ..Default::default() };
    let specs = [
        "osdt:block:q1:0.75:0.2",
        "static:0.9",
        "factor:0.95",
        "sequential:1",
    ];
    let mut rows = Vec::new();
    for spec in specs {
        let row = bench::run_eval(&rt, &tok, &ds, spec, &opts)?;
        rows.push(vec![
            row.policy.clone(),
            format!("{:.2}", row.accuracy * 100.0),
            format!("{:.1}", row.tokens_per_sec),
            format!("{:.1}", row.mean_steps),
            format!("{:.1}", row.mean_latency_ms),
        ]);
    }
    println!(
        "\n{}",
        bench::render_table(
            &["policy", "acc%", "tokens/s", "steps/seq", "latency ms"],
            &rows
        )
    );
    Ok(())
}
