"""Synthetic task generators — the stand-ins for GPQA / GSM8K / HumanEval.

Three tasks with the same evaluation *contracts* as the paper's benchmarks
(see DESIGN.md §1):

- ``synth-qa``   (GPQA analog): 4-way multiple-choice over a fixed synthetic
  knowledge base the model memorises at train time (closed-book retrieval).
- ``synth-math`` (GSM8K analog): 2-op arithmetic chains decoded with
  intermediate steps and a ``#### <answer>`` tail.
- ``synth-code`` (HumanEval analog): string-transform programs whose output
  is *executed* by the Rust-side interpreter and judged functionally.

Everything is deterministic given the seed. The eval JSONL files written by
``write_datasets`` are the ground truth the Rust workload/eval modules load.
"""

from __future__ import annotations

import json
import os
import random
from dataclasses import dataclass

from . import vocab

# ---------------------------------------------------------------------------
# Sequence geometry — must match model.py / model_config.json.
# ---------------------------------------------------------------------------
PROMPT_LEN = 64      # prompt region, [BOS] + text + [PAD]...
BLOCK_LEN = 32       # semi-AR block size (paper uses 32)
NUM_BLOCKS = 3
GEN_LEN = BLOCK_LEN * NUM_BLOCKS
SEQ_LEN = PROMPT_LEN + GEN_LEN

TASKS = ("synth-qa", "synth-math", "synth-code")

# ---------------------------------------------------------------------------
# synth-qa: fixed knowledge base entity -> class
# ---------------------------------------------------------------------------
QA_CLASSES = ["rok", "lum", "dax", "fen"]
_QA_CONSONANTS = "bcdfghjklmnpqrstvwxz"
_QA_VOWELS = "aeiou"


def qa_knowledge_base(seed: int = 7, n_entities: int = 128) -> dict[str, str]:
    """Deterministic entity->class map. The model memorises this at train
    time; eval questions query the same KB (closed-book, like GPQA's fixed
    expert knowledge)."""
    rng = random.Random(seed)
    entities: list[str] = []
    seen = set()
    while len(entities) < n_entities:
        e = (
            rng.choice(_QA_CONSONANTS)
            + rng.choice(_QA_VOWELS)
            + rng.choice(_QA_CONSONANTS)
        )
        if e not in seen:
            seen.add(e)
            entities.append(e)
    return {e: rng.choice(QA_CLASSES) for e in entities}


def make_qa_example(kb: dict[str, str], rng: random.Random) -> dict:
    # fixed option order: the model must recall the entity's class from its
    # memorised KB (closed-book, like GPQA's fixed expert knowledge) and
    # name the matching letter
    entity = rng.choice(sorted(kb))
    truth = kb[entity]
    order = QA_CLASSES[:]
    letter = "ABCD"[order.index(truth)]
    opts = " ".join(f"({l}) {c}" for l, c in zip("ABCD", order))
    prompt = f"Q: class of {entity}? {opts}"
    completion = f"A: ({letter}) {truth} #### {letter}"
    return {
        "task": "synth-qa",
        "prompt": prompt,
        "completion": completion,
        "answer": letter,
        "meta": {"entity": entity, "class": truth, "options": order},
    }


# ---------------------------------------------------------------------------
# synth-math: small arithmetic chains with worked steps
# ---------------------------------------------------------------------------

def make_math_example(rng: random.Random) -> dict:
    # single-digit operands, 2 ops, intermediates in 0..18 — hard enough to
    # show accuracy/throughput trade-offs, easy enough for a ~0.6M-param
    # char model to learn at build time (GSM8K's *contract*, scaled down)
    n_ops = 2
    acc = rng.randint(1, 9)
    terms = [str(acc)]
    steps = []
    for _ in range(n_ops):
        op = rng.choice(["+", "-"])
        operand = rng.randint(1, 9)
        if op == "-" and acc - operand < 0:
            op = "+"
        nxt = acc + operand if op == "+" else acc - operand
        steps.append(f"{acc}{op}{operand}={nxt}")
        terms.append(f"{op}{operand}")
        acc = nxt
    prompt = f"Q: {''.join(terms)}=?"
    completion = f"A: {'; '.join(steps)}. #### {acc}"
    return {
        "task": "synth-math",
        "prompt": prompt,
        "completion": completion,
        "answer": str(acc),
        "meta": {"expr": "".join(terms), "value": acc},
    }


# ---------------------------------------------------------------------------
# synth-code: string-transform programs (functionally evaluated)
# ---------------------------------------------------------------------------
CODE_OPS = ("rev", "dup", "rot1", "swap", "drop2")


def run_code_op(op: str, s: str) -> str:
    """The reference interpreter. The Rust eval module implements the exact
    same semantics (property-tested against these via shared fixtures)."""
    if op == "rev":
        return s[::-1]
    if op == "dup":
        return "".join(c + c for c in s)
    if op == "rot1":
        return "".join(chr((ord(c) - 97 + 1) % 26 + 97) for c in s)
    if op == "swap":
        out = list(s)
        for i in range(0, len(s) - 1, 2):
            out[i], out[i + 1] = out[i + 1], out[i]
        return "".join(out)
    if op == "drop2":
        return "".join(c for i, c in enumerate(s) if i % 2 == 0)
    raise ValueError(f"unknown op {op}")


def make_code_example(rng: random.Random) -> dict:
    op = rng.choice(CODE_OPS)
    n = rng.randint(3, 5)
    s = "".join(rng.choice("abcdefghijklmnopqrstuvwxyz") for _ in range(n))
    out = run_code_op(op, s)
    prompt = f"op: {op} | in: {s}"
    completion = f"out: {out}"
    return {
        "task": "synth-code",
        "prompt": prompt,
        "completion": completion,
        "answer": out,
        "meta": {"op": op, "input": s},
    }


# ---------------------------------------------------------------------------
# Tokenisation into the fixed sequence layout
# ---------------------------------------------------------------------------

def encode_example(prompt: str, completion: str) -> tuple[list[int], list[int]]:
    """Return (tokens, loss_mask) of length SEQ_LEN.

    Prompt region: [BOS] prompt [PAD]*. Gen region: completion [EOS]* —
    the EOS fill teaches the model to terminate, which is what produces the
    late-step confidence dynamics the paper observes.
    loss_mask is 1 exactly on the gen region (LLaDA SFT objective).
    """
    p = [vocab.BOS] + vocab.encode(prompt)
    if len(p) > PROMPT_LEN:
        raise ValueError(f"prompt too long: {len(p)} > {PROMPT_LEN}")
    p = p + [vocab.PAD] * (PROMPT_LEN - len(p))
    c = vocab.encode(completion)
    if len(c) > GEN_LEN - 1:
        raise ValueError(f"completion too long: {len(c)} > {GEN_LEN - 1}")
    c = c + [vocab.EOS] * (GEN_LEN - len(c))
    mask = [0] * PROMPT_LEN + [1] * GEN_LEN
    return p + c, mask


def make_example(task: str, kb: dict[str, str], rng: random.Random) -> dict:
    if task == "synth-qa":
        return make_qa_example(kb, rng)
    if task == "synth-math":
        return make_math_example(rng)
    if task == "synth-code":
        return make_code_example(rng)
    raise ValueError(f"unknown task {task}")


def training_batch_stream(seed: int, batch_size: int):
    """Infinite stream of (tokens, loss_mask) batches over the task mixture."""
    import numpy as np

    kb = qa_knowledge_base()
    rng = random.Random(seed)
    while True:
        toks, masks = [], []
        for _ in range(batch_size):
            ex = make_example(rng.choice(TASKS), kb, rng)
            t, m = encode_example(ex["prompt"], ex["completion"])
            toks.append(t)
            masks.append(m)
        yield np.asarray(toks, dtype=np.int32), np.asarray(masks, dtype=np.int32)


def write_datasets(out_dir: str, n_eval: int = 160, seed: int = 1234) -> None:
    """Write per-task eval JSONL files consumed by the Rust workload module.

    Eval uses a *different* seed stream than training, so questions are
    unseen combinations (though the qa KB and op/char distributions are the
    same — that is the point: task-level, not instance-level, structure).
    """
    os.makedirs(out_dir, exist_ok=True)
    kb = qa_knowledge_base()
    for ti, task in enumerate(TASKS):
        rng = random.Random(seed + 1000 * ti)  # str hash is not stable across runs
        path = os.path.join(out_dir, f"{task}.eval.jsonl")
        with open(path, "w") as f:
            for _ in range(n_eval):
                ex = make_example(task, kb, rng)
                # validate it fits the sequence layout
                encode_example(ex["prompt"], ex["completion"])
                f.write(json.dumps(ex) + "\n")
