"""AOT entrypoint: train (cached), lower every serving variant to HLO text,
and emit all build artifacts consumed by the Rust coordinator.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the image's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under artifacts/:
  model_config.json          geometry + vocab + param order + variant table
  weights.bin                OSDTW001 tensor container (frozen order)
  checkpoint.npz             training checkpoint (cache for rebuilds)
  fwd_conf_b{1,2,4}.hlo.txt  (weights..., tokens)                -> (conf, argmax)
  fwd_full_kv_b1.hlo.txt     (weights..., tokens)                -> (conf, argmax, k$, v$)
  fwd_window_b1.hlo.txt      (weights..., win_tokens, start, k$, v$) -> (conf, argmax)
  fwd_window_b{2..32}.hlo.txt  (weights..., win_tokens, starts, k$[B], v$[B])
                             -> (conf, argmax)   [stacked window pass]
  kv_gather_b{2..32}.hlo.txt (k_0..k_{B-1}, v_0..v_{B-1}) -> (k$[B], v$[B])
                             [weights-free on-device cache stacking for the
                              device-residency path — see rust DESIGN.md §10]

Window-path variants are emitted at every bucket in WINDOW_BATCH_SIZES
(1, 2, 4, 8, 16, 32): any scheduler group pads up to the cheapest bucket
that fits. Bucketed fwd_window_accept variants carry a row_live i32[B]
input whose 0 rows contribute nothing (padding); plain fwd_window padding
rows are simply dropped host-side. fwd_conf stays at b <= 4 — full passes
are the cold path.
  logits_b1.hlo.txt          (weights..., tokens)                -> (logits,)  [debug]
  data/<task>.eval.jsonl     synthetic eval datasets

Weights are HLO *parameters* (not baked constants): the Rust runtime loads
weights.bin once, uploads each tensor, and reuses the buffers every call.
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod

BATCH_SIZES = (1, 2, 4)
# Window-path buckets (stacked window / fused accept / kv_gather). Larger
# than the conf buckets on purpose: steady-state occupancy lives in window
# passes, so that is where co-execution width pays (ROADMAP item 1).
WINDOW_BATCH_SIZES = (1, 2, 4, 8, 16, 32)
WINDOW = data_mod.BLOCK_LEN


def expected_variants() -> list[str]:
    """The full variant table lower_variants must emit — asserted there and
    by test_aot.py, so a bucket silently dropping out of the AOT loop fails
    fast instead of surfacing as a runtime fallback to exact-b1 passes."""
    names = [f"fwd_conf_b{b}" for b in BATCH_SIZES]
    names.append("fwd_full_kv_b1")
    names += [f"fwd_window_b{b}" for b in WINDOW_BATCH_SIZES]
    if model_mod.VOCAB < (1 << 16) and WINDOW < (1 << 15):
        names += [f"fwd_window_accept_b{b}" for b in WINDOW_BATCH_SIZES]
    names += [f"kv_gather_b{b}" for b in WINDOW_BATCH_SIZES if b > 1]
    names.append("logits_b1")
    return names


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights_bin(path: str, params) -> None:
    """OSDTW001 container: [magic][n][per tensor: name_len name dtype_code
    ndim dims... f32 payload]. Little-endian throughout."""
    order = model_mod.param_order()
    assert set(order) == set(params), "param_order drifted from init_params"
    with open(path, "wb") as f:
        f.write(b"OSDTW001")
        f.write(struct.pack("<I", len(order)))
        for name in order:
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", 0))  # dtype code 0 = f32
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes(order="C"))


def _weights_tuple(params):
    """Params as a positional tuple in frozen order (HLO parameter list)."""
    return tuple(params[k] for k in model_mod.param_order())


def _from_tuple(ws):
    order = model_mod.param_order()
    return dict(zip(order, ws))


def lower_variants(params, out_dir: str) -> dict:
    """Lower every serving variant; returns the variant table for
    model_config.json."""
    order = model_mod.param_order()
    n_w = len(order)
    shapes = {k: tuple(int(d) for d in np.asarray(params[k]).shape) for k in order}
    w_specs = tuple(
        jax.ShapeDtypeStruct(shapes[k], jnp.float32) for k in order
    )
    s = model_mod.SEQ_LEN
    lhs = model_mod.N_LAYERS, model_mod.N_HEADS, s, model_mod.HEAD_DIM
    variants = {}

    def emit(name, fn, *arg_specs):
        lowered = jax.jit(fn).lower(*w_specs, *arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(f"[aot] {fname}: {len(text) / 1e6:.2f} MB")
        return fname

    for b in BATCH_SIZES:
        def fwd_conf(*args, _b=b):
            ws, tokens = args[:n_w], args[n_w]
            return model_mod.fwd_conf(_from_tuple(ws), tokens, use_pallas=True)

        fname = emit(
            f"fwd_conf_b{b}", fwd_conf, jax.ShapeDtypeStruct((b, s), jnp.int32)
        )
        variants[f"fwd_conf_b{b}"] = {
            "file": fname,
            "batch": b,
            "inputs": ["weights...", f"tokens i32[{b},{s}]"],
            "outputs": [f"conf f32[{b},{s}]", f"argmax i32[{b},{s}]"],
        }

    def fwd_full_kv(*args):
        ws, tokens = args[:n_w], args[n_w]
        return model_mod.fwd_full_kv(_from_tuple(ws), tokens, use_pallas=True)

    fname = emit(
        "fwd_full_kv_b1", fwd_full_kv, jax.ShapeDtypeStruct((1, s), jnp.int32)
    )
    variants["fwd_full_kv_b1"] = {
        "file": fname,
        "batch": 1,
        "inputs": ["weights...", f"tokens i32[1,{s}]"],
        "outputs": [
            f"conf f32[1,{s}]",
            f"argmax i32[1,{s}]",
            f"k_cache f32{list(lhs)}",
            f"v_cache f32{list(lhs)}",
        ],
    }

    def fwd_window(*args):
        ws = args[:n_w]
        win_tokens, start, kc, vc = args[n_w : n_w + 4]
        return model_mod.fwd_window(
            _from_tuple(ws), win_tokens, start, kc, vc, use_pallas=True
        )

    fname = emit(
        "fwd_window_b1",
        fwd_window,
        jax.ShapeDtypeStruct((1, WINDOW), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct(lhs, jnp.float32),
        jax.ShapeDtypeStruct(lhs, jnp.float32),
    )
    variants["fwd_window_b1"] = {
        "file": fname,
        "batch": 1,
        "inputs": [
            "weights...",
            f"window_tokens i32[1,{WINDOW}]",
            "start i32[]",
            f"k_cache f32{list(lhs)}",
            f"v_cache f32{list(lhs)}",
        ],
        "outputs": [f"conf f32[1,{WINDOW}]", f"argmax i32[1,{WINDOW}]"],
    }

    # fused window + on-device threshold acceptance (rust DESIGN.md §11):
    # per-step D2H is compact acceptance, never full confidence rows. The
    # compact payload packs (pos << 16) | token into one i32, so models
    # whose geometry cannot be represented skip the variants entirely (the
    # Rust runtime then keeps its legacy host-rule fallback).
    accept_packable = model_mod.VOCAB < (1 << 16) and WINDOW < (1 << 15)
    if not accept_packable:
        print(
            f"[aot] skipping fwd_window_accept_b*: vocab {model_mod.VOCAB} / "
            f"window {WINDOW} exceed the (pos<<16)|token packing"
        )
    n_chunks = -(-WINDOW // model_mod.ACCEPT_CHUNK)
    accept_outputs = [
        "count i32[{b}]",
        "fell_back i32[{b}]",
        "step_mean f32[{b}]",
    ] + [
        f"packed_{j} i32[{{b}},{model_mod.ACCEPT_CHUNK}]" for j in range(n_chunks)
    ]

    def fwd_window_accept_b1(*args):
        ws = args[:n_w]
        win_tokens, start, kc, vc, taus, factors = args[n_w : n_w + 6]
        return model_mod.fwd_window_accept(
            _from_tuple(ws), win_tokens, start, kc, vc, taus, factors,
            use_pallas=True,
        )

    if accept_packable:
        fname = emit(
            "fwd_window_accept_b1",
            fwd_window_accept_b1,
            jax.ShapeDtypeStruct((1, WINDOW), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct(lhs, jnp.float32),
            jax.ShapeDtypeStruct(lhs, jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        )
        variants["fwd_window_accept_b1"] = {
            "file": fname,
            "batch": 1,
            "inputs": [
                "weights...",
                f"window_tokens i32[1,{WINDOW}]",
                "start i32[]",
                f"k_cache f32{list(lhs)}",
                f"v_cache f32{list(lhs)}",
                "taus f32[1]",
                "factors f32[1]",
            ],
            "outputs": [o.format(b=1) for o in accept_outputs],
        }

    # batched window + on-device cache stacking (device residency path),
    # at every bucket size — groups pad up to the cheapest bucket that fits
    for b in WINDOW_BATCH_SIZES:
        if b == 1:
            continue
        blhs = (b, *lhs)

        def fwd_window_b(*args):
            ws = args[:n_w]
            win_tokens, starts, kc, vc = args[n_w : n_w + 4]
            return model_mod.fwd_window_batch(
                _from_tuple(ws), win_tokens, starts, kc, vc, use_pallas=True
            )

        fname = emit(
            f"fwd_window_b{b}",
            fwd_window_b,
            jax.ShapeDtypeStruct((b, WINDOW), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct(blhs, jnp.float32),
            jax.ShapeDtypeStruct(blhs, jnp.float32),
        )
        variants[f"fwd_window_b{b}"] = {
            "file": fname,
            "batch": b,
            "inputs": [
                "weights...",
                f"window_tokens i32[{b},{WINDOW}]",
                f"starts i32[{b}]",
                f"k_caches f32{list(blhs)}",
                f"v_caches f32{list(blhs)}",
            ],
            "outputs": [f"conf f32[{b},{WINDOW}]", f"argmax i32[{b},{WINDOW}]"],
        }

        if accept_packable:
            def fwd_window_accept_b(*args):
                ws = args[:n_w]
                win_tokens, starts, kc, vc, taus, factors, live = (
                    args[n_w : n_w + 7]
                )
                return model_mod.fwd_window_accept_batch(
                    _from_tuple(ws), win_tokens, starts, kc, vc, taus, factors,
                    live, use_pallas=True,
                )

            fname = emit(
                f"fwd_window_accept_b{b}",
                fwd_window_accept_b,
                jax.ShapeDtypeStruct((b, WINDOW), jnp.int32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                jax.ShapeDtypeStruct(blhs, jnp.float32),
                jax.ShapeDtypeStruct(blhs, jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
            )
            variants[f"fwd_window_accept_b{b}"] = {
                "file": fname,
                "batch": b,
                "inputs": [
                    "weights...",
                    f"window_tokens i32[{b},{WINDOW}]",
                    f"starts i32[{b}]",
                    f"k_caches f32{list(blhs)}",
                    f"v_caches f32{list(blhs)}",
                    f"taus f32[{b}]",
                    f"factors f32[{b}]",
                    f"row_live i32[{b}]",
                ],
                "outputs": [o.format(b=b) for o in accept_outputs],
            }

        def kv_gather_b(*caches, _b=b):
            return model_mod.kv_gather(caches[:_b], caches[_b:])

        # weights-free: lower over 2B per-row cache specs only
        lowered = jax.jit(kv_gather_b).lower(
            *([jax.ShapeDtypeStruct(lhs, jnp.float32)] * (2 * b))
        )
        fname = f"kv_gather_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"[aot] {fname}")
        variants[f"kv_gather_b{b}"] = {
            "file": fname,
            "batch": b,
            "inputs": [f"k_i, v_i f32{list(lhs)} x {2 * b} (no weights)"],
            "outputs": [f"k f32{list(blhs)}", f"v f32{list(blhs)}"],
        }

    def logits_fn(*args):
        ws, tokens = args[:n_w], args[n_w]
        return (model_mod.fwd_logits(_from_tuple(ws), tokens, use_pallas=True),)

    fname = emit("logits_b1", logits_fn, jax.ShapeDtypeStruct((1, s), jnp.int32))
    variants["logits_b1"] = {
        "file": fname,
        "batch": 1,
        "inputs": ["weights...", f"tokens i32[1,{s}]"],
        "outputs": [f"logits f32[1,{s},{model_mod.VOCAB}]"],
    }
    assert set(variants) == set(expected_variants()), (
        sorted(set(variants) ^ set(expected_variants()))
    )
    return variants


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=train_mod.TRAIN_STEPS)
    ap.add_argument(
        "--retrain", action="store_true", help="ignore cached checkpoint"
    )
    args = ap.parse_args()

    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    ckpt = os.path.join(out, "checkpoint.npz")

    if os.path.exists(ckpt) and not args.retrain:
        print(f"[aot] loading cached checkpoint {ckpt}")
        params = train_mod.load_checkpoint(ckpt)
    else:
        print(f"[aot] training {args.train_steps} steps ...")
        params, _ = train_mod.train(steps=args.train_steps)
        train_mod.save_checkpoint(ckpt, params)

    write_weights_bin(os.path.join(out, "weights.bin"), params)
    variants = lower_variants(params, out)

    cfg = model_mod.model_config()
    cfg["variants"] = variants
    cfg["weights_file"] = "weights.bin"
    with open(os.path.join(out, "model_config.json"), "w") as f:
        json.dump(cfg, f, indent=1)

    data_mod.write_datasets(os.path.join(out, "data"))
    write_golden(params, os.path.join(out, "golden_fwd.json"))
    print("[aot] done")


def write_golden(params, path: str) -> None:
    """Cross-language golden vector: the Rust integration test compares its
    PJRT execution of the artifacts against these JAX-computed values."""
    from . import vocab

    prompt = "Q: 3+4-2=?"
    ids = [vocab.BOS] + vocab.encode(prompt)
    ids += [vocab.PAD] * (data_mod.PROMPT_LEN - len(ids))
    ids += [vocab.MASK] * data_mod.GEN_LEN
    toks = jnp.asarray([ids], jnp.int32)
    conf, arg = model_mod.fwd_conf(params, toks, use_pallas=True)
    gold = {
        "prompt": prompt,
        "conf_64_72": [float(x) for x in np.asarray(conf[0, 64:72])],
        "argmax_64_72": [int(x) for x in np.asarray(arg[0, 64:72])],
    }
    with open(path, "w") as f:
        json.dump(gold, f)


if __name__ == "__main__":
    main()
