"""L1 Pallas kernel: fused softmax-confidence + argmax.

The OSDT/Fast-dLLM scoring path needs, for every position j, only
``conf[j] = max_v softmax(logits[j])`` and ``argmax[j]`` — not the softmax
itself. Materialising a (seq, vocab) softmax in HBM each denoising step is
pure waste; this kernel reduces each vocab row to two scalars in one pass:

    running max  m, running sum  z = sum exp(l - m)   (rescaled on new max)
    conf = exp(m - m) / z = 1 / z,   argmax = index attaining m

Grid = seq tiles; vocab is swept in VMEM-resident tiles via an inner loop.
HBM traffic per step drops from O(seq*vocab) to O(seq) on the output side —
the TPU restatement of the paper's "cut redundant work on the scoring path".

interpret=True for CPU PJRT; validated against ``ref.confidence_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conf_kernel(x_ref, conf_ref, arg_ref, *, block_v: int, vocab: int):
    """One seq-tile program: streaming max/sum/argmax over vocab tiles."""
    block_s = x_ref.shape[0]
    num_v = vocab // block_v

    def body(vb, carry):
        m_i, z_i, a_i = carry
        x = jax.lax.dynamic_slice_in_dim(x_ref[...], vb * block_v, block_v, 1)
        x = x.astype(jnp.float32)                       # (bs, bv)
        tile_m = jnp.max(x, axis=-1)
        tile_a = jnp.argmax(x, axis=-1).astype(jnp.int32) + vb * block_v
        m_new = jnp.maximum(m_i, tile_m)
        z_new = z_i * jnp.exp(m_i - m_new) + jnp.sum(
            jnp.exp(x - m_new[:, None]), axis=-1
        )
        # strict '>' keeps the first (lowest-id) maximum, matching jnp.argmax
        a_new = jnp.where(tile_m > m_i, tile_a, a_i)
        return m_new, z_new, a_new

    m0 = jnp.full((block_s,), -jnp.inf, jnp.float32)
    z0 = jnp.zeros((block_s,), jnp.float32)
    a0 = jnp.zeros((block_s,), jnp.int32)
    _, z, a = jax.lax.fori_loop(0, num_v, body, (m0, z0, a0))
    conf_ref[...] = 1.0 / z
    arg_ref[...] = a


@functools.partial(jax.jit, static_argnames=("block_s", "block_v"))
def confidence(
    logits: jnp.ndarray, *, block_s: int = 32, block_v: int = 64
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(seq, vocab) logits -> (conf (seq,) f32, argmax (seq,) i32).

    vocab is padded to a multiple of block_v with -inf (padding can never win
    the max, so numerics are unchanged).
    """
    seq, vocab = logits.shape
    if seq % block_s:
        raise ValueError(f"seq {seq} not divisible by block_s {block_s}")
    pad_v = (-vocab) % block_v
    if pad_v:
        logits = jnp.pad(
            logits, ((0, 0), (0, pad_v)), constant_values=-jnp.inf
        )
        vocab += pad_v
    return pl.pallas_call(
        functools.partial(_conf_kernel, block_v=block_v, vocab=vocab),
        grid=(seq // block_s,),
        in_specs=[pl.BlockSpec((block_s, vocab), lambda sb: (sb, 0))],
        out_specs=[
            pl.BlockSpec((block_s,), lambda sb: (sb,)),
            pl.BlockSpec((block_s,), lambda sb: (sb,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((seq,), jnp.float32),
            jax.ShapeDtypeStruct((seq,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(logits)
