"""L1 Pallas kernel: row-parallel LayerNorm (+ optional affine).

The model applies LayerNorm 2·L+1 times per forward; fusing the two
reduction passes (mean, variance) and the normalisation into one
VMEM-resident sweep removes two HBM round-trips per call relative to the
naive lowering.

TPU mapping: grid = row tiles; each program instance owns a
(block_rows, d_model) tile in VMEM, computes mean/var with row-wise
reductions (VPU), normalises and applies the affine in-place, and writes
the tile back once. d_model stays resident — for this model (d=64..256) a
tile is a few KiB, far under the VMEM budget.

interpret=True as everywhere (CPU PJRT); validated against
``ref.layernorm_ref`` by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) * (x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def layernorm(
    x: jnp.ndarray,
    g: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_rows: int = 32,
    eps: float = 1e-5,
) -> jnp.ndarray:
    """LayerNorm over the last axis of (rows, d); rows must divide evenly
    into block_rows tiles (the model's sequence layout guarantees this)."""
    rows, d = x.shape
    if g.shape != (d,) or b.shape != (d,):
        raise ValueError(f"affine shapes {g.shape}/{b.shape} != ({d},)")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows {rows} not divisible by block_rows {br}")
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, g, b)
