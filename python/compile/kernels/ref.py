"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness ground truth*: pytest asserts the Pallas kernels
(interpret=True) match these to tight tolerances across hypothesis-generated
shapes. They are also the implementations used on the training path (Pallas
has no autodiff without a custom VJP, and training does not need the fused
kernels).
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional (unmasked) multi-head attention.

    Shapes: q,k,v = (heads, seq, head_dim) -> (heads, seq, head_dim).
    Softmax computed in f32 regardless of input dtype.
    """
    head_dim = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    logits = (
        jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", w, v.astype(jnp.float32)).astype(q.dtype)


def layernorm_ref(
    x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    """LayerNorm over the last axis, f32 statistics."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return (((xf - mu) / jnp.sqrt(var + eps)) * g + b).astype(x.dtype)


def confidence_ref(logits: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-position greedy confidence and candidate token.

    logits: (seq, vocab) -> (conf (seq,) f32, argmax (seq,) i32).
    conf[j] = max_v softmax(logits[j])[v] = 1 / sum_v exp(l_v - max_l).
    argmax ties break toward the lower id (matches the Pallas kernel).
    """
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    z = jnp.sum(jnp.exp(x - m), axis=-1)
    conf = 1.0 / z
    arg = jnp.argmax(x, axis=-1).astype(jnp.int32)
    return conf, arg
