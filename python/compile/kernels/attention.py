"""L1 Pallas kernel: flash-style bidirectional attention.

TPU mapping (DESIGN.md §2): the CUDA threadblock/shared-memory tiling of the
original GPU setting becomes a VMEM tiling expressed with ``BlockSpec``:

- grid = (heads, q_blocks); each program instance owns one (head, q-tile),
- the KV loop is an inner ``fori_loop`` over k-tiles, so the online-softmax
  accumulator for a q-tile never leaves VMEM (one HBM write per output tile),
- both contractions are plain ``(block_q, d) x (d, block_k)`` matmuls so a
  real TPU lowering maps them onto the MXU systolic array.

``interpret=True`` is mandatory on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls. Numerics are validated against ``ref.attention_ref`` by
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, kv_len: int):
    """One (head, q-tile) program: online-softmax over k-tiles."""
    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    head_dim = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    block_q = q.shape[0]
    num_k_blocks = kv_len // block_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], kb * block_k, block_k, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], kb * block_k, block_k, 0)
        s = (q @ k.astype(jnp.float32).T) * scale          # (bq, bk) — MXU
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)  # MXU
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)
    m0 = jnp.full((block_q,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, num_k_blocks, body, (acc0, m0, l0))
    o_ref[0] = (acc / l_i[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = 32,
    block_k: int = 32,
) -> jnp.ndarray:
    """Flash attention over q=(heads, q_len, d), k/v=(heads, kv_len, d).

    q_len and kv_len may differ (the KV-window decode variant attends a
    32-token window against the full cached sequence); both must divide
    evenly into their tile sizes (the model's sequence layout guarantees
    this: 160 = 5 x 32)."""
    heads, q_len, head_dim = q.shape
    kv_len = k.shape[1]
    if q_len % block_q or kv_len % block_k:
        raise ValueError(f"lens {q_len}/{kv_len} not divisible by {block_q}/{block_k}")
    grid = (heads, q_len // block_q)
    return pl.pallas_call(
        functools.partial(_attn_kernel, block_k=block_k, kv_len=kv_len),
        grid=grid,
        in_specs=[
            # one q-tile per program
            pl.BlockSpec((1, block_q, head_dim), lambda h, qb: (h, qb, 0)),
            # full K/V rows for this head stay resident; the kernel slices
            # k-tiles out of them (VMEM footprint: kv_len*d, tiny here)
            pl.BlockSpec((1, kv_len, head_dim), lambda h, qb: (h, 0, 0)),
            pl.BlockSpec((1, kv_len, head_dim), lambda h, qb: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda h, qb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, q_len, head_dim), q.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(q, k, v)
