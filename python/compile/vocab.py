"""Character-level vocabulary for the tiny MDLM.

Single source of truth: the Rust tokenizer loads the exact same table from
``artifacts/model_config.json`` (emitted by aot.py), so the two sides can
never drift.

Layout (stable ids):
  0..3   special: [PAD], [MASK], [BOS], [EOS]
  4..    printable characters used by the synthetic tasks
"""

from __future__ import annotations

PAD, MASK, BOS, EOS = 0, 1, 2, 3
SPECIALS = ["[PAD]", "[MASK]", "[BOS]", "[EOS]"]

# Every character any synthetic task can emit. Order is frozen — changing it
# invalidates trained weights.
_CHARS = (
    "abcdefghijklmnopqrstuvwxyz"
    "ABCDEFGHIJKLMNOPQRSTUVWXYZ"
    "0123456789"
    " .,:;?!#+-*/=()<>'\"_|"
)

CHAR_TO_ID = {c: i + len(SPECIALS) for i, c in enumerate(_CHARS)}
ID_TO_CHAR = {i: c for c, i in CHAR_TO_ID.items()}
VOCAB_SIZE = len(SPECIALS) + len(_CHARS)


def encode(text: str) -> list[int]:
    """Encode a string to token ids. Unknown characters are a hard error —
    the task generators own the character set."""
    try:
        return [CHAR_TO_ID[c] for c in text]
    except KeyError as e:  # pragma: no cover - generator bug guard
        raise ValueError(f"character not in vocab: {e.args[0]!r}") from e


def decode(ids) -> str:
    """Decode ids to text, dropping special tokens."""
    return "".join(ID_TO_CHAR[int(i)] for i in ids if int(i) >= len(SPECIALS))


def vocab_table() -> list[str]:
    """Id -> surface form table, for model_config.json."""
    return SPECIALS + list(_CHARS)
