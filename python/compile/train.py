"""Build-time training of the tiny MDLM on the synthetic task mixture.

Hand-rolled AdamW (the image has no optax) + cosine LR with warmup. This is
the one-time substitute for "download LLaDA-8B" (DESIGN.md §1): it produces a
mask predictor with real, structured confidence dynamics over the same three
task families the paper evaluates.

Run via aot.py (``make artifacts``); a checkpoint is cached under artifacts/
so retraining only happens when the model/data code changes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod

# Training hyperparameters — chosen so `make artifacts` finishes in minutes
# on the CPU PJRT backend while reaching useful task accuracy.
BATCH_SIZE = 32
TRAIN_STEPS = 2400
PEAK_LR = 3e-3
WARMUP = 100
WEIGHT_DECAY = 0.01
SEED = 0

# AdamW moments
B1, B2, EPS = 0.9, 0.98, 1e-9

# weight-decay applies to matrices only, not gains/biases/embeddings
_DECAY_SUFFIXES = ("wq", "wk", "wv", "wo", "w1", "w2", "head")


def _decay_mask(params):
    return {
        k: float(any(k.split(".")[-1] == s for s in _DECAY_SUFFIXES))
        for k in params
    }


def lr_schedule(step: int | jnp.ndarray):
    warm = jnp.minimum(1.0, (step + 1) / WARMUP)
    prog = jnp.clip((step - WARMUP) / max(1, TRAIN_STEPS - WARMUP), 0.0, 1.0)
    return PEAK_LR * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def make_update_step(decay_mask):
    @jax.jit
    def update(params, m, v, step, tokens, loss_mask, key):
        loss, grads = jax.value_and_grad(model_mod.diffusion_loss)(
            params, tokens, loss_mask, key
        )
        lr = lr_schedule(step)
        t = step + 1

        def upd(p, g, m_, v_, dk):
            m_n = B1 * m_ + (1 - B1) * g
            v_n = B2 * v_ + (1 - B2) * g * g
            mhat = m_n / (1 - B1**t)
            vhat = v_n / (1 - B2**t)
            p_n = p - lr * (mhat / (jnp.sqrt(vhat) + EPS) + WEIGHT_DECAY * dk * p)
            return p_n, m_n, v_n

        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            new_p[k], new_m[k], new_v[k] = upd(
                params[k], grads[k], m[k], v[k], decay_mask[k]
            )
        return new_p, new_m, new_v, loss

    return update


def train(steps: int = TRAIN_STEPS, seed: int = SEED, log_every: int = 100):
    """Train from scratch; returns (params, loss_history)."""
    params = model_mod.init_params(seed)
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    update = make_update_step(_decay_mask(params))
    stream = data_mod.training_batch_stream(seed=seed + 17, batch_size=BATCH_SIZE)
    key = jax.random.PRNGKey(seed + 1)
    losses = []
    t0 = time.time()
    for step in range(steps):
        tokens, loss_mask = next(stream)
        key, sub = jax.random.split(key)
        params, m, v, loss = update(
            params, m, v, jnp.asarray(step), jnp.asarray(tokens),
            jnp.asarray(loss_mask), sub,
        )
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(
                f"[train] step {step:5d}  loss {float(loss):7.4f}  "
                f"lr {float(lr_schedule(step)):.2e}  {dt:6.1f}s",
                flush=True,
            )
    return params, losses


def save_checkpoint(path: str, params) -> None:
    np.savez(path, **{k: np.asarray(p) for k, p in params.items()})


def load_checkpoint(path: str):
    z = np.load(path)
    return {k: jnp.asarray(z[k]) for k in z.files}
