"""L2: the MDLM mask predictor (tiny LLaDA-style transformer) in JAX.

Bidirectional (no causal mask) pre-LN transformer over the fixed sequence
layout of data.py: ``[BOS] prompt [PAD]... || gen region``. The gen region is
what diffusion decoding fills in; the network predicts token distributions
at every position simultaneously (mask-predictor semantics).

Three inference variants are AOT-lowered by aot.py:

- ``fwd_conf``     tokens -> (conf, argmax)                 (no-cache path)
- ``fwd_full_kv``  tokens -> (conf, argmax, k_cache, v_cache)
                   (block-start refresh of the Fast-dLLM dual cache)
- ``fwd_window``   (window_tokens, start, k_cache, v_cache) -> (conf, argmax)
                   (within-block steps: only the 32-token window is
                   recomputed; all other K/V come from the cache)

The training path (train.py) uses the same ``fwd_logits`` with
``use_pallas=False`` so the graph is autodiff-able; the AOT path flips the
Pallas kernels on so the serving artifacts actually contain the L1 kernels.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import data as data_mod
from . import vocab
from .kernels import ref
from .kernels.attention import attention as pallas_attention
from .kernels.conf import confidence as pallas_confidence
from .kernels.layernorm import layernorm as pallas_layernorm

# ---------------------------------------------------------------------------
# Geometry — frozen alongside the trained weights.
# ---------------------------------------------------------------------------
D_MODEL = 64
N_LAYERS = 4
N_HEADS = 4
HEAD_DIM = D_MODEL // N_HEADS
D_FF = 256
SEQ_LEN = data_mod.SEQ_LEN
VOCAB = vocab.VOCAB_SIZE

Params = dict[str, Any]


def init_params(seed: int = 0) -> Params:
    """Scaled-normal init. Layout (and therefore weights.bin order) is
    ``param_order()`` — frozen."""
    key = jax.random.PRNGKey(seed)
    ks = iter(jax.random.split(key, 8 + 12 * N_LAYERS))

    def normal(shape, scale):
        return (jax.random.normal(next(ks), shape) * scale).astype(jnp.float32)

    p: Params = {
        "tok_emb": normal((VOCAB, D_MODEL), 0.02),
        "pos_emb": normal((SEQ_LEN, D_MODEL), 0.02),
        "lnf_g": jnp.ones((D_MODEL,), jnp.float32),
        "lnf_b": jnp.zeros((D_MODEL,), jnp.float32),
        "head": normal((D_MODEL, VOCAB), 0.02),
    }
    for l in range(N_LAYERS):
        p[f"l{l}.ln1_g"] = jnp.ones((D_MODEL,), jnp.float32)
        p[f"l{l}.ln1_b"] = jnp.zeros((D_MODEL,), jnp.float32)
        p[f"l{l}.wq"] = normal((D_MODEL, D_MODEL), 0.02)
        p[f"l{l}.wk"] = normal((D_MODEL, D_MODEL), 0.02)
        p[f"l{l}.wv"] = normal((D_MODEL, D_MODEL), 0.02)
        # residual-branch projections scaled down by depth (GPT-2 style)
        p[f"l{l}.wo"] = normal((D_MODEL, D_MODEL), 0.02 / (2 * N_LAYERS) ** 0.5)
        p[f"l{l}.ln2_g"] = jnp.ones((D_MODEL,), jnp.float32)
        p[f"l{l}.ln2_b"] = jnp.zeros((D_MODEL,), jnp.float32)
        p[f"l{l}.w1"] = normal((D_MODEL, D_FF), 0.02)
        p[f"l{l}.b1"] = jnp.zeros((D_FF,), jnp.float32)
        p[f"l{l}.w2"] = normal((D_FF, D_MODEL), 0.02 / (2 * N_LAYERS) ** 0.5)
        p[f"l{l}.b2"] = jnp.zeros((D_MODEL,), jnp.float32)
    return p


def param_order() -> list[str]:
    """Frozen flattening order for weights.bin / HLO parameter lists."""
    names = ["tok_emb", "pos_emb"]
    for l in range(N_LAYERS):
        names += [
            f"l{l}.{n}"
            for n in (
                "ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
                "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
            )
        ]
    names += ["lnf_g", "lnf_b", "head"]
    return names


def _ln(x, g, b, eps=1e-5, use_pallas: bool = False):
    if use_pallas and LN_PALLAS and x.ndim == 2:
        return pallas_layernorm(x, g, b, eps=eps)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _split_heads(x):  # (S, D) -> (H, S, Dh)
    s = x.shape[0]
    return x.reshape(s, N_HEADS, HEAD_DIM).transpose(1, 0, 2)


def _merge_heads(x):  # (H, S, Dh) -> (S, D)
    return x.transpose(1, 0, 2).reshape(x.shape[1], D_MODEL)


# L1 kernel tile sizes — tunable at AOT time (perf pass; see DESIGN.md
# §Perf). Defaults match the 32-token block structure; larger q-tiles trade
# grid-iteration overhead for VMEM footprint.
ATTN_BLOCK_Q = 32
ATTN_BLOCK_K = 32
CONF_BLOCK_V = 64
# The Pallas LayerNorm is validated (tests) and TPU-targeted, but measured
# 12% slower than XLA's native LN fusion under CPU interpret mode, so the
# CPU serving artifacts leave it off (EXPERIMENTS.md §Perf, iteration 2).
LN_PALLAS = False


def _attend(q, k, v, use_pallas: bool):
    if not use_pallas:
        return ref.attention_ref(q, k, v)
    bq = min(ATTN_BLOCK_Q, q.shape[1])
    bk = min(ATTN_BLOCK_K, k.shape[1])
    return pallas_attention(q, k, v, block_q=bq, block_k=bk)


def _layer(p: Params, l: int, h, use_pallas: bool, kv_splice=None, kv_out=None):
    """One transformer block over (S, D) hidden.

    kv_splice: optional fn (k_w, v_w) -> (k_full, v_full) used by the window
    variant, where attention keys/values span the full cached sequence while
    ``h`` covers only the active window.
    kv_out: optional list collecting (k, v) per layer (cache refresh).
    """
    a_in = _ln(h, p[f"l{l}.ln1_g"], p[f"l{l}.ln1_b"], use_pallas=use_pallas)
    q = _split_heads(a_in @ p[f"l{l}.wq"])
    k = _split_heads(a_in @ p[f"l{l}.wk"])
    v = _split_heads(a_in @ p[f"l{l}.wv"])
    if kv_out is not None:
        kv_out.append((k, v))
    if kv_splice is not None:
        k, v = kv_splice(k, v)
    att = _merge_heads(_attend(q, k, v, use_pallas)) @ p[f"l{l}.wo"]
    h = h + att
    m_in = _ln(h, p[f"l{l}.ln2_g"], p[f"l{l}.ln2_b"], use_pallas=use_pallas)
    m = jax.nn.gelu(m_in @ p[f"l{l}.w1"] + p[f"l{l}.b1"]) @ p[f"l{l}.w2"] + p[f"l{l}.b2"]
    return h + m


def _fwd_hidden(p: Params, tokens: jnp.ndarray, use_pallas: bool, kv_out=None):
    """tokens (S,) int32 -> final hidden (S, D)."""
    h = p["tok_emb"][tokens] + p["pos_emb"]
    for l in range(N_LAYERS):
        h = _layer(p, l, h, use_pallas, kv_out=kv_out)
    return _ln(h, p["lnf_g"], p["lnf_b"], use_pallas=use_pallas)


def fwd_logits(p: Params, tokens: jnp.ndarray, use_pallas: bool = False):
    """(B, S) int32 -> (B, S, V) f32 logits."""

    def one(t):
        return _fwd_hidden(p, t, use_pallas) @ p["head"]

    return jax.vmap(one)(tokens)


def _reduce_conf(logits2d, use_pallas: bool):
    if use_pallas:
        return pallas_confidence(logits2d, block_v=CONF_BLOCK_V)
    return ref.confidence_ref(logits2d)


def fwd_conf(p: Params, tokens: jnp.ndarray, use_pallas: bool = True):
    """(B, S) -> (conf (B,S) f32, argmax (B,S) i32) — the serving hot path.

    The (B*S, V) logits are reduced by the fused Pallas confidence kernel;
    full logits never leave the computation.
    """
    b, s = tokens.shape
    logits = fwd_logits(p, tokens, use_pallas).reshape(b * s, VOCAB)
    conf, arg = _reduce_conf(logits, use_pallas)
    return conf.reshape(b, s), arg.reshape(b, s)


# ---------------------------------------------------------------------------
# Fast-dLLM dual-cache variants (batch 1, matching the paper's serving setup)
# ---------------------------------------------------------------------------

def fwd_full_kv(p: Params, tokens: jnp.ndarray, use_pallas: bool = True):
    """(1, S) -> (conf (1,S), argmax (1,S), k_cache, v_cache (L,H,S,Dh)).

    Run at each block boundary: refreshes every layer's K/V (prefix *and*
    suffix — the DualCache design) for reuse by fwd_window within the block.
    """
    kv: list[tuple[jnp.ndarray, jnp.ndarray]] = []
    hidden = _fwd_hidden(p, tokens[0], use_pallas, kv_out=kv)
    logits = hidden @ p["head"]
    conf, arg = _reduce_conf(logits, use_pallas)
    k_cache = jnp.stack([k for k, _ in kv])
    v_cache = jnp.stack([v for _, v in kv])
    return conf[None, :], arg[None, :], k_cache, v_cache


def fwd_window(
    p: Params,
    window_tokens: jnp.ndarray,  # (1, W) i32
    start: jnp.ndarray,          # () i32 — absolute position of the window
    k_cache: jnp.ndarray,        # (L, H, S, Dh) f32
    v_cache: jnp.ndarray,
    use_pallas: bool = True,
):
    """Within-block step: recompute only the active window.

    The window's own K/V are refreshed and spliced into the cached full-
    sequence K/V (dynamic_update_slice at ``start``); queries come from the
    window only. Everything outside the window uses stale K/V — exactly the
    Fast-dLLM DualCache approximation.
    Returns (conf (1, W) f32, argmax (1, W) i32).
    """
    t = window_tokens[0]
    w = t.shape[0]
    pos = jax.lax.dynamic_slice_in_dim(p["pos_emb"], start, w, 0)
    h = p["tok_emb"][t] + pos

    for l in range(N_LAYERS):
        def splice(k_w, v_w, _l=l):
            kf = jax.lax.dynamic_update_slice(k_cache[_l], k_w, (0, start, 0))
            vf = jax.lax.dynamic_update_slice(v_cache[_l], v_w, (0, start, 0))
            return kf, vf

        h = _layer(p, l, h, use_pallas, kv_splice=splice)
    logits = _ln(h, p["lnf_g"], p["lnf_b"], use_pallas=use_pallas) @ p["head"]
    conf, arg = _reduce_conf(logits, use_pallas)
    return conf[None, :], arg[None, :]


def fwd_window_batch(
    p: Params,
    window_tokens: jnp.ndarray,  # (B, W) i32
    starts: jnp.ndarray,         # (B,) i32 — per-row absolute window position
    k_caches: jnp.ndarray,       # (B, L, H, S, Dh) f32
    v_caches: jnp.ndarray,
    use_pallas: bool = True,
):
    """Batched Fast-dLLM window step: row ``b`` recomputes its own window
    against its own cached K/V — result-identical to ``B`` independent
    ``fwd_window`` calls (the Rust scheduler relies on this to keep batched
    decode token-identical to solo decode).

    Returns (conf (B, W) f32, argmax (B, W) i32). The stacked cache inputs
    are produced on device by the ``kv_gather_b{B}`` stacking variant, so
    the serving path never ships K/V through the host.
    """

    def one(t, start, kc, vc):
        conf, arg = fwd_window(p, t[None, :], start, kc, vc, use_pallas=use_pallas)
        return conf[0], arg[0]

    return jax.vmap(one)(window_tokens, starts, k_caches, v_caches)


def kv_gather(ks, vs):
    """Stack per-sequence dual caches into the batched window layout:
    B × (L, H, S, Dh) -> (B, L, H, S, Dh), for k and v. Lowered per batch
    size as ``kv_gather_b{B}`` — a weights-free stacking executable the Rust
    runtime feeds with per-row device buffers (device cache residency)."""
    return jnp.stack(ks), jnp.stack(vs)


# ---------------------------------------------------------------------------
# Fused threshold acceptance (rust DESIGN.md §11): the policy decision runs
# on device, so steady-state window steps never download confidence rows.
# ---------------------------------------------------------------------------

# Width of one packed-commit output chunk. Chunks are separate executable
# outputs, so the host downloads ceil(count / ACCEPT_CHUNK) of them instead
# of a full block row: per-step device->host traffic is O(accepted tokens).
ACCEPT_CHUNK = 8


def accept_from_conf(conf, arg, window_tokens, taus, factors, row_live=None):
    """Apply the per-row acceptance rule to a window pass's (conf, argmax)
    rows entirely on device, returning only compact acceptance.

    The masked set is derived on device: position ``i`` is masked iff
    ``window_tokens[i] == [MASK]`` — identical to the Rust
    ``DecodeTask::masked`` bookkeeping, so no mask upload is needed. Per
    row, in f32 (matching the Rust host reference ``runtime::accept_rows``):

        raw[i]  = masked[i] and (conf[i] > tau  or  conf[i] >= factor*cmax)

    where ``cmax`` is the row's max masked confidence and a disabled
    disjunct is ``+inf`` (which can never accept). If ``raw`` is empty the
    single most confident masked position is accepted — the argmax liveness
    fallback, ties -> lowest index, matching ``policy::argmax``.

    ``row_live`` (``(B,) i32``, optional) marks padding rows of a bucketed
    batch: a row with ``row_live == 0`` has its masked set forced empty, so
    it contributes zero commits, a zero step mean, and never trips the
    liveness fallback — whatever garbage its padded window/cache rows hold.
    Bucketed variants (b >= 2) always take it; batch-1 never pads.

    Returns ``(count (B,) i32, fell_back (B,) i32, step_mean (B,) f32,
    *chunks)`` where each chunk is a (B, ACCEPT_CHUNK) i32 output; entry
    ``e`` of a row holds ``(pos << 16) | token`` for the e-th accepted
    position (ascending), ``-1`` beyond ``count``. ``step_mean`` is the
    masked-mean confidence — the drift-signature scalar the Rust
    ProfileRegistry consumes.
    """
    w = conf.shape[1]
    m = window_tokens == vocab.MASK
    if row_live is not None:
        # dead rows: empty masked set => no raw accepts, no fallback
        # (has_mask False), count 0, step_mean 0/max(0,1) = 0, every packed
        # entry -1. One mask covers all four contributions.
        m = m & (row_live[:, None] > 0)
    mconf = jnp.where(m, conf, -jnp.inf)
    cmax = jnp.max(mconf, axis=1, keepdims=True)
    raw = m & ((conf > taus[:, None]) | (conf >= factors[:, None] * cmax))
    has_mask = jnp.any(m, axis=1)
    fell_back = ~jnp.any(raw, axis=1) & has_mask
    fb = (jnp.arange(w)[None, :] == jnp.argmax(mconf, axis=1, keepdims=True)) & m
    accept = jnp.where(fell_back[:, None], fb, raw)
    count = jnp.sum(accept, axis=1).astype(jnp.int32)
    mcnt = jnp.sum(m, axis=1)
    step_mean = jnp.sum(jnp.where(m, conf, 0.0), axis=1) / jnp.maximum(mcnt, 1)
    # front-pack accepted entries in ascending position order (stable sort
    # on "position if accepted else W")
    pos = jnp.arange(w, dtype=jnp.int32)[None, :]
    order = jnp.argsort(jnp.where(accept, pos, w), axis=1)
    entry = jnp.where(accept, (pos << 16) | arg, -1)
    packed = jnp.take_along_axis(entry, order, axis=1)
    chunks = tuple(
        packed[:, i : i + ACCEPT_CHUNK] for i in range(0, w, ACCEPT_CHUNK)
    )
    return (count, fell_back.astype(jnp.int32), step_mean, *chunks)


def fwd_window_accept(
    p,
    window_tokens,  # (1, W) i32
    start,          # () i32
    k_cache,        # (L, H, S, Dh) f32
    v_cache,
    taus,           # (1,) f32 — threshold rule cutoff, +inf to disable
    factors,        # (1,) f32 — factor-max rule, +inf to disable
    use_pallas: bool = True,
):
    """Batch-1 fused window step: ``fwd_window`` + on-device acceptance."""
    conf, arg = fwd_window(p, window_tokens, start, k_cache, v_cache, use_pallas)
    return accept_from_conf(conf, arg, window_tokens, taus, factors)


def fwd_window_accept_batch(
    p,
    window_tokens,  # (B, W) i32
    starts,         # (B,) i32
    k_caches,       # (B, L, H, S, Dh) f32
    v_caches,
    taus,           # (B,) f32
    factors,        # (B,) f32
    row_live,       # (B,) i32 — 1 for real rows, 0 for bucket padding
    use_pallas: bool = True,
):
    """Batched fused window step: row ``b`` recomputes its own window and
    applies its own acceptance rule — row-identical to ``B`` independent
    ``fwd_window_accept`` calls on the live rows, while ``row_live == 0``
    padding rows contribute nothing (see ``accept_from_conf``). Stacked
    cache inputs come from ``kv_gather_b{B}`` on the device-residency
    path; groups smaller than the compiled bucket pad up to it."""
    conf, arg = fwd_window_batch(
        p, window_tokens, starts, k_caches, v_caches, use_pallas
    )
    return accept_from_conf(conf, arg, window_tokens, taus, factors, row_live)


# ---------------------------------------------------------------------------
# Training objective (LLaDA SFT): random-ratio masking over the gen region,
# 1/t-weighted CE on masked positions.
# ---------------------------------------------------------------------------

def diffusion_loss(p: Params, tokens, loss_mask, key):
    """tokens (B,S) i32 clean sequences; loss_mask (B,S) {0,1} gen region.

    t ~ U(eps, 1) per example; each gen-region token is replaced by [MASK]
    w.p. t; loss = sum over masked positions of CE / t, normalised.
    """
    b, s = tokens.shape
    kt, km = jax.random.split(key)
    t = jax.random.uniform(kt, (b, 1), minval=0.05, maxval=1.0)
    u = jax.random.uniform(km, (b, s))
    masked = (u < t) & (loss_mask == 1)
    noised = jnp.where(masked, vocab.MASK, tokens)
    logits = fwd_logits(p, noised, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_lp = jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]
    w = masked.astype(jnp.float32) / t
    return -jnp.sum(tok_lp * w) / (jnp.sum(w) + 1e-8)


def model_config() -> dict:
    """Emitted into artifacts/model_config.json — the Rust side's single
    source of truth for geometry + vocab."""
    return {
        "d_model": D_MODEL,
        "n_layers": N_LAYERS,
        "n_heads": N_HEADS,
        "head_dim": HEAD_DIM,
        "d_ff": D_FF,
        "vocab_size": VOCAB,
        "seq_len": SEQ_LEN,
        "prompt_len": data_mod.PROMPT_LEN,
        "gen_len": data_mod.GEN_LEN,
        "block_len": data_mod.BLOCK_LEN,
        "num_blocks": data_mod.NUM_BLOCKS,
        "pad_id": vocab.PAD,
        "mask_id": vocab.MASK,
        "bos_id": vocab.BOS,
        "eos_id": vocab.EOS,
        "vocab": vocab.vocab_table(),
        "param_order": param_order(),
    }
