"""Unit tests for scripts/bench_diff.py (the CI bench-trajectory gate)."""

import copy
import importlib.util
import json
import pathlib

import pytest

SCRIPT = (
    pathlib.Path(__file__).resolve().parents[2] / "scripts" / "bench_diff.py"
)
spec = importlib.util.spec_from_file_location("bench_diff", SCRIPT)
bench_diff = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_diff)


def make_doc(tps_by_policy, provenance="measured"):
    return {
        "bench": "serving_load",
        "schema": 2,
        "mode": "smoke",
        "seed": 7,
        "provenance": provenance,
        "rows": [
            {
                "policy": policy,
                "cache": "on",
                "residency": "sim",
                "rate": 8.0,
                "ok": 6,
                "n": 6,
                "p50_ms": 40.0,
                "p95_ms": 90.0,
                "p99_ms": 120.0,
                "ttft_p50_ms": 5.0,
                "ttft_p95_ms": 12.0,
                "ttft_p99_ms": 15.0,
                "tok_p50_ms": 1.2,
                "tok_p95_ms": 2.8,
                "tok_p99_ms": 3.5,
                "tokens_per_sec": tps,
                "bytes_per_token": 64.0,
                "cache_upload_bytes": 0,
                "fused_frac": 1.0,
                "bytes_per_step": 256.0,
                "occ_mean": 1.5,
                "occ_peak": 4,
            }
            for policy, tps in tps_by_policy.items()
        ],
    }


def write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def run(tmp_path, base_doc, cur_doc, extra=()):
    base = write(tmp_path, "base.json", base_doc)
    cur = write(tmp_path, "cur.json", cur_doc)
    return bench_diff.main([base, cur, *extra])


def test_identical_runs_pass(tmp_path):
    doc = make_doc({"osdt": 900.0, "static": 700.0})
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0


def test_small_drop_within_threshold_passes(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 905.0})  # -9.5%
    assert run(tmp_path, base, cur) == 0


def test_large_drop_fails(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 880.0})  # -12%
    assert run(tmp_path, base, cur) == 1


def test_improvement_passes(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 1500.0})
    assert run(tmp_path, base, cur) == 0


def test_seed_provenance_only_warns(tmp_path):
    base = make_doc({"osdt": 1000.0}, provenance="seed")
    cur = make_doc({"osdt": 500.0})  # -50%, but baseline is bootstrap
    assert run(tmp_path, base, cur) == 0


def test_custom_threshold(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 905.0})  # -9.5% fails a 5% gate
    assert run(tmp_path, base, cur, ["--threshold", "0.05"]) == 1


def test_unmatched_rows_are_noted_not_gated(tmp_path):
    base = make_doc({"osdt": 1000.0, "static": 700.0})
    cur = make_doc({"osdt": 990.0, "sequential": 100.0})
    assert run(tmp_path, base, cur) == 0


def test_no_common_rows_is_an_error(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"static": 700.0})
    with pytest.raises(SystemExit):
        run(tmp_path, base, cur)


def test_schema_mismatch_is_an_error(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 1000.0})
    cur["schema"] = 1
    with pytest.raises(SystemExit):
        run(tmp_path, base, cur)


def test_wrong_bench_is_an_error(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 1000.0})
    cur["bench"] = "table1"
    with pytest.raises(SystemExit):
        run(tmp_path, base, cur)


def test_committed_snapshot_is_valid_and_warn_only(tmp_path):
    """The snapshot in bench/trajectory/ must parse, be schema 2, and be
    marked as bootstrap (warn-only) until CI replaces it with a measured
    artifact."""
    snap = SCRIPT.parents[1] / "bench" / "trajectory" / "BENCH_serving.json"
    doc = json.loads(snap.read_text())
    assert doc["bench"] == "serving_load"
    assert doc["schema"] == 2
    assert doc["provenance"] == "seed"
    assert doc["mode"] == "smoke"
    keys = {bench_diff.key(r) for r in doc["rows"]}
    assert len(keys) == len(doc["rows"]), "duplicate (policy,cache,residency,rate)"
    for row in doc["rows"]:
        for f in (
            "tokens_per_sec",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "ttft_p50_ms",
            "ttft_p95_ms",
            "ttft_p99_ms",
            "tok_p50_ms",
            "tok_p95_ms",
            "tok_p99_ms",
        ):
            assert isinstance(row[f], (int, float)), f"{f} missing in {row}"
    # diffing the snapshot against itself must pass its own gate
    assert bench_diff.main([str(snap), str(snap)]) == 0
