"""Unit tests for scripts/bench_diff.py (the CI bench-trajectory gate)."""

import copy
import importlib.util
import json
import pathlib

import pytest

SCRIPT = (
    pathlib.Path(__file__).resolve().parents[2] / "scripts" / "bench_diff.py"
)
spec = importlib.util.spec_from_file_location("bench_diff", SCRIPT)
bench_diff = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_diff)


def make_doc(tps_by_policy, provenance="measured"):
    return {
        "bench": "serving_load",
        "schema": 2,
        "mode": "smoke",
        "seed": 7,
        "provenance": provenance,
        "rows": [
            {
                "policy": policy,
                "cache": "on",
                "residency": "sim",
                "rate": 8.0,
                "ok": 6,
                "n": 6,
                "p50_ms": 40.0,
                "p95_ms": 90.0,
                "p99_ms": 120.0,
                "ttft_p50_ms": 5.0,
                "ttft_p95_ms": 12.0,
                "ttft_p99_ms": 15.0,
                "tok_p50_ms": 1.2,
                "tok_p95_ms": 2.8,
                "tok_p99_ms": 3.5,
                "tokens_per_sec": tps,
                "bytes_per_token": 64.0,
                "cache_upload_bytes": 0,
                "fused_frac": 1.0,
                "bytes_per_step": 256.0,
                "occ_mean": 1.5,
                "occ_peak": 4,
            }
            for policy, tps in tps_by_policy.items()
        ],
    }


def write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def run(tmp_path, base_doc, cur_doc, extra=()):
    base = write(tmp_path, "base.json", base_doc)
    cur = write(tmp_path, "cur.json", cur_doc)
    return bench_diff.main([base, cur, *extra])


def test_identical_runs_pass(tmp_path):
    doc = make_doc({"osdt": 900.0, "static": 700.0})
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0


def test_small_drop_within_threshold_passes(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 905.0})  # -9.5%
    assert run(tmp_path, base, cur) == 0


def test_large_drop_fails(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 880.0})  # -12%
    assert run(tmp_path, base, cur) == 1


def test_improvement_passes(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 1500.0})
    assert run(tmp_path, base, cur) == 0


def test_seed_provenance_only_warns(tmp_path):
    base = make_doc({"osdt": 1000.0}, provenance="seed")
    cur = make_doc({"osdt": 500.0})  # -50%, but baseline is bootstrap
    assert run(tmp_path, base, cur) == 0


def test_custom_threshold(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 905.0})  # -9.5% fails a 5% gate
    assert run(tmp_path, base, cur, ["--threshold", "0.05"]) == 1


def test_unmatched_rows_are_noted_not_gated(tmp_path):
    base = make_doc({"osdt": 1000.0, "static": 700.0})
    cur = make_doc({"osdt": 990.0, "sequential": 100.0})
    assert run(tmp_path, base, cur) == 0


def test_no_common_rows_is_an_error(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"static": 700.0})
    with pytest.raises(SystemExit):
        run(tmp_path, base, cur)


def test_schema_mismatch_is_an_error(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 1000.0})
    cur["schema"] = 1
    with pytest.raises(SystemExit):
        run(tmp_path, base, cur)


def test_wrong_bench_is_an_error(tmp_path):
    base = make_doc({"osdt": 1000.0})
    cur = make_doc({"osdt": 1000.0})
    cur["bench"] = "table1"
    with pytest.raises(SystemExit):
        run(tmp_path, base, cur)


def elision_rows(on_executed=36.0, on_elided=54.0, off_executed=90.0):
    """A matched elide-off/elide-on pair as emitted by the bench's elision
    A/B section."""
    rows = []
    for cache, executed, elided in (
        ("elide-off", off_executed, 0.0),
        ("elide-on", on_executed, on_elided),
    ):
        rows.append(
            {
                "policy": "osdt:step-block:q1:1:0",
                "cache": cache,
                "residency": "sim",
                "rate": 8.0,
                "ok": 6,
                "n": 6,
                "p50_ms": 12.0,
                "p95_ms": 28.0,
                "p99_ms": 36.0,
                "ttft_p50_ms": 4.0,
                "ttft_p95_ms": 10.0,
                "ttft_p99_ms": 13.0,
                "tok_p50_ms": 0.4,
                "tok_p95_ms": 0.9,
                "tok_p99_ms": 1.2,
                "tokens_per_sec": 5000.0,
                "bytes_per_token": 100.0,
                "cache_upload_bytes": 18000,
                "fused_frac": 0.9,
                "bytes_per_step": 650.0,
                "steps_executed": executed,
                "steps_elided": elided,
                "occ_mean": 1.4,
                "occ_peak": 4,
            }
        )
    return rows


def with_elision(doc, **kwargs):
    doc = copy.deepcopy(doc)
    doc["rows"].extend(elision_rows(**kwargs))
    return doc


def test_consistent_elision_rows_pass(tmp_path):
    doc = with_elision(make_doc({"osdt": 900.0}))
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0


def test_elision_saving_nothing_fails_even_on_seed_baseline(tmp_path):
    # deterministic-sim invariant: never waived by warn-only provenance
    base = with_elision(make_doc({"osdt": 900.0}, provenance="seed"))
    cur = with_elision(
        make_doc({"osdt": 900.0}, provenance="seed"),
        on_executed=90.0,
        off_executed=90.0,
    )
    assert run(tmp_path, base, cur) == 1


def test_elision_with_zero_elided_steps_fails(tmp_path):
    doc = make_doc({"osdt": 900.0})
    cur = with_elision(copy.deepcopy(doc), on_elided=0.0)
    assert run(tmp_path, with_elision(doc), cur) == 1


def test_elide_on_row_missing_steps_fields_fails(tmp_path):
    doc = with_elision(make_doc({"osdt": 900.0}))
    cur = copy.deepcopy(doc)
    for row in cur["rows"]:
        if row["cache"] == "elide-on":
            del row["steps_executed"]
            del row["steps_elided"]
    assert run(tmp_path, doc, cur) == 1


def test_elide_on_without_matching_off_row_fails(tmp_path):
    doc = with_elision(make_doc({"osdt": 900.0}))
    cur = copy.deepcopy(doc)
    cur["rows"] = [r for r in cur["rows"] if r["cache"] != "elide-off"]
    assert run(tmp_path, doc, cur) == 1


def test_artifacts_without_elision_rows_pass_vacuously(tmp_path):
    # pre-elision artifacts carry no elide-* rows and must keep gating
    doc = make_doc({"osdt": 900.0})
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0
    assert bench_diff.check_elision(doc, "x.json") == []


def predictive_rows(
    err_p95=3.0, shed_rate=0.0, p50=18.0, drop_fifo=False
):
    """A matched fifo/predictive pair as emitted by the bench's admission
    A/B section (DESIGN.md §15)."""
    rows = []
    for cache in ("fifo", "predictive"):
        if cache == "fifo" and drop_fifo:
            continue
        rows.append(
            {
                "policy": "osdt:step-block:q1:1:0",
                "cache": cache,
                "residency": "sim",
                "rate": 1000000,
                "ok": 48,
                "n": 48,
                "p50_ms": 3.0,
                "p95_ms": 7.0,
                "p99_ms": 9.0,
                "ttft_p50_ms": 2.0,
                "ttft_p95_ms": 6.0,
                "ttft_p99_ms": 7.5,
                "tok_p50_ms": 0.03,
                "tok_p95_ms": 0.07,
                "tok_p99_ms": 0.09,
                "tokens_per_sec": 20000.0,
                "bytes_per_token": 120.0,
                "cache_upload_bytes": 140000,
                "fused_frac": 0.9,
                "bytes_per_step": 650.0,
                "steps_executed": 984.0,
                "steps_elided": 0.0,
                "admission_p95_ms": 4.0 if cache == "fifo" else 2.5,
                "predicted_steps_p50": p50,
                "forecast_abs_err_p95": err_p95,
                "shed_rate": shed_rate,
                "occ_mean": 1.0,
                "occ_peak": 1,
            }
        )
    return rows


def with_predictive(doc, **kwargs):
    doc = copy.deepcopy(doc)
    doc["rows"].extend(predictive_rows(**kwargs))
    return doc


def test_consistent_predictive_rows_pass(tmp_path):
    doc = with_predictive(make_doc({"osdt": 900.0}))
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0


def test_nonfinite_forecast_error_fails_even_on_seed_baseline(tmp_path):
    # deterministic-sim invariant: never waived by warn-only provenance
    base = with_predictive(make_doc({"osdt": 900.0}, provenance="seed"))
    cur = with_predictive(
        make_doc({"osdt": 900.0}, provenance="seed"), err_p95=float("nan")
    )
    assert run(tmp_path, base, cur) == 1


def test_null_forecast_error_fails(tmp_path):
    # an empty histogram serializes as JSON null — not a silent pass
    doc = with_predictive(make_doc({"osdt": 900.0}))
    cur = copy.deepcopy(doc)
    for row in cur["rows"]:
        if row["cache"] == "predictive":
            row["forecast_abs_err_p95"] = None
    assert run(tmp_path, doc, cur) == 1


def test_nonzero_shed_rate_at_low_rate_fails(tmp_path):
    doc = with_predictive(make_doc({"osdt": 900.0}))
    cur = with_predictive(make_doc({"osdt": 900.0}), shed_rate=0.04)
    assert run(tmp_path, doc, cur) == 1


def test_zero_predicted_steps_fails(tmp_path):
    doc = with_predictive(make_doc({"osdt": 900.0}))
    cur = with_predictive(make_doc({"osdt": 900.0}), p50=0.0)
    assert run(tmp_path, doc, cur) == 1


def test_predictive_row_missing_fields_fails(tmp_path):
    doc = with_predictive(make_doc({"osdt": 900.0}))
    cur = copy.deepcopy(doc)
    for row in cur["rows"]:
        if row["cache"] == "predictive":
            del row["predicted_steps_p50"]
            del row["shed_rate"]
    assert run(tmp_path, doc, cur) == 1


def test_predictive_without_matching_fifo_row_fails(tmp_path):
    doc = with_predictive(make_doc({"osdt": 900.0}))
    cur = with_predictive(make_doc({"osdt": 900.0}), drop_fifo=True)
    assert run(tmp_path, doc, cur) == 1


def test_artifacts_without_predictive_rows_pass_vacuously(tmp_path):
    # pre-predictive artifacts carry no fifo/predictive rows and keep gating
    doc = make_doc({"osdt": 900.0})
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0
    assert bench_diff.check_predictive(doc, "x.json") == []


def fleet_rows(
    failover_ok=6, steady_shed=0.0, failover_tps=7200.0, drop_steady=False
):
    """A matched fleet-steady/fleet-failover pair as emitted by the
    bench's fleet-tier A/B section (DESIGN.md §16)."""
    rows = []
    for cache in ("fleet-steady", "fleet-failover"):
        if cache == "fleet-steady" and drop_steady:
            continue
        rows.append(
            {
                "policy": "static:0.9",
                "cache": cache,
                "residency": "sim",
                "rate": 1000000,
                "ok": 6 if cache == "fleet-steady" else failover_ok,
                "n": 6,
                "p50_ms": 3.5,
                "p95_ms": 7.0 if cache == "fleet-steady" else 14.0,
                "p99_ms": 9.0 if cache == "fleet-steady" else 19.0,
                "ttft_p50_ms": 1.2,
                "ttft_p95_ms": 2.6,
                "ttft_p99_ms": 3.1,
                "tok_p50_ms": 0.11,
                "tok_p95_ms": 0.25,
                "tok_p99_ms": 0.35,
                "tokens_per_sec": (
                    9000.0 if cache == "fleet-steady" else failover_tps
                ),
                "bytes_per_token": 160.0,
                "cache_upload_bytes": 0,
                "fused_frac": 0.0,
                "bytes_per_step": 950.0,
                "steps_executed": 96.0,
                "steps_elided": 0.0,
                "admission_p95_ms": 0.0,
                "predicted_steps_p50": 0.0,
                "forecast_abs_err_p95": 0.0,
                "shed_rate": steady_shed if cache == "fleet-steady" else 0.0,
                "occ_mean": 1.0,
                "occ_peak": 1,
            }
        )
    return rows


def with_fleet(doc, **kwargs):
    doc = copy.deepcopy(doc)
    doc["rows"].extend(fleet_rows(**kwargs))
    return doc


def test_consistent_fleet_rows_pass(tmp_path):
    doc = with_fleet(make_doc({"osdt": 900.0}))
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0


def test_fleet_failover_dropping_requests_fails_even_on_seed(tmp_path):
    # zero-drop failover is a hard invariant, never waived by provenance
    base = with_fleet(make_doc({"osdt": 900.0}, provenance="seed"))
    cur = with_fleet(
        make_doc({"osdt": 900.0}, provenance="seed"), failover_ok=5
    )
    assert run(tmp_path, base, cur) == 1


def test_fleet_steady_shedding_fails(tmp_path):
    doc = with_fleet(make_doc({"osdt": 900.0}))
    cur = with_fleet(make_doc({"osdt": 900.0}), steady_shed=0.2)
    assert run(tmp_path, doc, cur) == 1


def test_fleet_zero_throughput_fails(tmp_path):
    doc = with_fleet(make_doc({"osdt": 900.0}))
    cur = with_fleet(make_doc({"osdt": 900.0}), failover_tps=0.0)
    assert run(tmp_path, doc, cur) == 1


def test_fleet_failover_without_matching_steady_row_fails(tmp_path):
    doc = with_fleet(make_doc({"osdt": 900.0}))
    cur = with_fleet(make_doc({"osdt": 900.0}), drop_steady=True)
    assert run(tmp_path, doc, cur) == 1


def test_fleet_row_missing_fields_fails(tmp_path):
    doc = with_fleet(make_doc({"osdt": 900.0}))
    cur = copy.deepcopy(doc)
    for row in cur["rows"]:
        if row["cache"] == "fleet-failover":
            del row["ok"]
            del row["shed_rate"]
    assert run(tmp_path, doc, cur) == 1


def test_artifacts_without_fleet_rows_pass_vacuously(tmp_path):
    # pre-fleet artifacts carry no fleet-* rows and must keep gating
    doc = make_doc({"osdt": 900.0})
    assert run(tmp_path, doc, copy.deepcopy(doc)) == 0
    assert bench_diff.check_fleet(doc, "x.json") == []


def test_committed_snapshot_is_valid_and_warn_only(tmp_path):
    """The snapshot in bench/trajectory/ must parse, be schema 2, and be
    marked as bootstrap (warn-only) until CI replaces it with a measured
    artifact."""
    snap = SCRIPT.parents[1] / "bench" / "trajectory" / "BENCH_serving.json"
    doc = json.loads(snap.read_text())
    assert doc["bench"] == "serving_load"
    assert doc["schema"] == 2
    assert doc["provenance"] == "seed"
    assert doc["mode"] == "smoke"
    keys = {bench_diff.key(r) for r in doc["rows"]}
    assert len(keys) == len(doc["rows"]), "duplicate (policy,cache,residency,rate)"
    for row in doc["rows"]:
        for f in (
            "tokens_per_sec",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "ttft_p50_ms",
            "ttft_p95_ms",
            "ttft_p99_ms",
            "tok_p50_ms",
            "tok_p95_ms",
            "tok_p99_ms",
        ):
            assert isinstance(row[f], (int, float)), f"{f} missing in {row}"
    # the elision, admission, and fleet A/B pairs must be present and
    # self-consistent
    caches = {r["cache"] for r in doc["rows"]}
    assert {"elide-off", "elide-on"} <= caches
    assert {"fifo", "predictive"} <= caches
    assert {"fleet-steady", "fleet-failover"} <= caches
    assert bench_diff.check_elision(doc, str(snap)) == []
    assert bench_diff.check_predictive(doc, str(snap)) == []
    assert bench_diff.check_fleet(doc, str(snap)) == []
    # diffing the snapshot against itself must pass its own gate
    assert bench_diff.main([str(snap), str(snap)]) == 0
