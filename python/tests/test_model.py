"""L2 correctness: model variants, shapes, cache consistency, loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile import vocab


@pytest.fixture(scope="module")
def params():
    return M.init_params(0)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(
        rng.integers(0, M.VOCAB, size=(2, M.SEQ_LEN)), jnp.int32
    )


def test_param_order_covers_params(params):
    assert set(M.param_order()) == set(params)
    assert len(M.param_order()) == len(set(M.param_order()))


def test_logits_shape(params, tokens):
    logits = M.fwd_logits(params, tokens)
    assert logits.shape == (2, M.SEQ_LEN, M.VOCAB)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_fwd_conf_pallas_vs_ref(params, tokens):
    c1, a1 = M.fwd_conf(params, tokens, use_pallas=True)
    c2, a2 = M.fwd_conf(params, tokens, use_pallas=False)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_fwd_conf_is_max_softmax(params, tokens):
    """conf must equal max softmax of the logits path."""
    logits = M.fwd_logits(params, tokens, use_pallas=False)
    probs = jax.nn.softmax(logits, axis=-1)
    c, a = M.fwd_conf(params, tokens, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(c), np.asarray(jnp.max(probs, axis=-1)), atol=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(jnp.argmax(logits, axis=-1))
    )


def test_full_kv_matches_fwd_conf(params, tokens):
    """The cache-refresh variant must produce identical conf/argmax to the
    plain forward (it is the same computation, plus K/V outputs)."""
    c1, a1 = M.fwd_conf(params, tokens[:1], use_pallas=False)
    c2, a2, kc, vc = M.fwd_full_kv(params, tokens[:1], use_pallas=False)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    L, H, S, Dh = M.N_LAYERS, M.N_HEADS, M.SEQ_LEN, M.HEAD_DIM
    assert kc.shape == (L, H, S, Dh) and vc.shape == (L, H, S, Dh)


def test_window_consistent_with_full_on_fresh_cache(params, tokens):
    """With a just-refreshed cache and unchanged tokens, the window variant
    must reproduce the full forward's conf/argmax on the window — the
    Fast-dLLM DualCache exactness condition at step 0 of a block."""
    t = tokens[:1]
    c_full, a_full, kc, vc = M.fwd_full_kv(params, t, use_pallas=False)
    start = D.PROMPT_LEN + D.BLOCK_LEN  # second gen block
    win = t[:, start : start + D.BLOCK_LEN]
    c_w, a_w = M.fwd_window(
        params, win, jnp.asarray(start, jnp.int32), kc, vc, use_pallas=False
    )
    np.testing.assert_allclose(
        np.asarray(c_w[0]),
        np.asarray(c_full[0, start : start + D.BLOCK_LEN]),
        atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(a_w[0]), np.asarray(a_full[0, start : start + D.BLOCK_LEN])
    )


def test_window_batch_matches_solo_rows(params, tokens):
    """The batched window variant must be row-identical to independent
    fwd_window calls — the contract the Rust scheduler's batched device
    path relies on — and kv_gather must be a pure stack."""
    rows = []
    starts = [D.PROMPT_LEN, D.PROMPT_LEN + D.BLOCK_LEN]
    caches = []
    for i, start in enumerate(starts):
        t = tokens[i % tokens.shape[0]][None, :]
        _, _, kc, vc = M.fwd_full_kv(params, t, use_pallas=False)
        win = t[:, start : start + D.BLOCK_LEN]
        c, a = M.fwd_window(
            params, win, jnp.asarray(start, jnp.int32), kc, vc, use_pallas=False
        )
        rows.append((win[0], c[0], a[0]))
        caches.append((kc, vc))
    kb, vb = M.kv_gather([kc for kc, _ in caches], [vc for _, vc in caches])
    assert kb.shape == (2, M.N_LAYERS, M.N_HEADS, M.SEQ_LEN, M.HEAD_DIM)
    np.testing.assert_array_equal(np.asarray(kb[1]), np.asarray(caches[1][0]))
    cb, ab = M.fwd_window_batch(
        params,
        jnp.stack([w for w, _, _ in rows]),
        jnp.asarray(starts, jnp.int32),
        kb,
        vb,
        use_pallas=False,
    )
    for i, (_, c, a) in enumerate(rows):
        np.testing.assert_allclose(np.asarray(cb[i]), np.asarray(c), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(ab[i]), np.asarray(a))


def test_window_batch_padded_bucket_matches_solo(params, tokens):
    """A group smaller than its bucket pads up to it (b8 here): the live
    rows must stay row-identical to independent fwd_window calls while the
    padding rows — pad tokens, recycled garbage caches — are simply extra
    output rows the host drops. This is the contract behind the bucketed
    padded dispatch (ISSUE 7): padding must never perturb live rows."""
    bucket, live = 8, 3
    starts, rows, caches = [], [], []
    for i in range(live):
        start = D.PROMPT_LEN + (i % D.NUM_BLOCKS) * D.BLOCK_LEN
        t = tokens[i % tokens.shape[0]][None, :]
        _, _, kc, vc = M.fwd_full_kv(params, t, use_pallas=False)
        win = t[:, start : start + D.BLOCK_LEN]
        c, a = M.fwd_window(
            params, win, jnp.asarray(start, jnp.int32), kc, vc, use_pallas=False
        )
        starts.append(start)
        rows.append((win[0], c[0], a[0]))
        caches.append((kc, vc))
    # padding rows: pad-token windows, start 0, row-0's cache repeated (any
    # cache-shaped buffer serves — mirroring runtime::gather_stack)
    pad_win = jnp.full((D.BLOCK_LEN,), vocab.PAD, jnp.int32)
    win_b = jnp.stack([w for w, _, _ in rows] + [pad_win] * (bucket - live))
    starts_b = jnp.asarray(starts + [0] * (bucket - live), jnp.int32)
    kb, vb = M.kv_gather(
        [k for k, _ in caches] + [caches[0][0]] * (bucket - live),
        [v for _, v in caches] + [caches[0][1]] * (bucket - live),
    )
    cb, ab = M.fwd_window_batch(
        params, win_b, starts_b, kb, vb, use_pallas=False
    )
    assert cb.shape == (bucket, D.BLOCK_LEN)
    for i, (_, c, a) in enumerate(rows):
        np.testing.assert_allclose(np.asarray(cb[i]), np.asarray(c), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(ab[i]), np.asarray(a))


def _accept_reference(conf, arg, window_tokens, tau, factor):
    """Numpy mirror of the fused acceptance rule (and of the Rust host
    reference ``runtime::accept_rows``): f32 math, strict > for the
    threshold disjunct, >= for the factor-max disjunct, argmax liveness
    fallback with ties -> lowest index."""
    conf = np.asarray(conf, np.float32)
    arg = np.asarray(arg, np.int32)
    masked = np.asarray(window_tokens) == vocab.MASK
    idx = np.where(masked)[0]
    if idx.size == 0:
        return [], False, 0.0
    cmax = np.float32(conf[idx].max())
    cut = np.float32(factor) * cmax
    sel = [
        int(i)
        for i in idx
        if conf[i] > np.float32(tau) or conf[i] >= cut
    ]
    fell_back = not sel
    if fell_back:
        best = idx[int(np.argmax(conf[idx]))]
        sel = [int(best)]
    return [(i, int(arg[i])) for i in sel], fell_back, float(conf[idx].mean())


def _unpack_accept(out, row):
    count, fell_back, step_mean = out[0], out[1], out[2]
    chunks = np.concatenate([np.asarray(c) for c in out[3:]], axis=1)
    pairs = []
    for e in range(int(count[row])):
        packed = int(chunks[row, e])
        assert packed >= 0, "packed entry missing below count"
        pairs.append((packed >> 16, packed & 0xFFFF))
    # entries beyond count must be -1 (nothing leaks past the compact set)
    assert all(int(x) == -1 for x in chunks[row, int(count[row]) :])
    return pairs, bool(fell_back[row]), float(step_mean[row])


def test_window_accept_row_identity(params, tokens):
    """The fused acceptance variant must be row-identical to applying the
    host decision rule to the plain batched window pass — the contract the
    Rust scheduler's fused fast path relies on. Exercises a threshold row
    and a factor-max row in one batch."""
    starts = [D.PROMPT_LEN, D.PROMPT_LEN + D.BLOCK_LEN]
    wins, caches = [], []
    for i, start in enumerate(starts):
        t = np.asarray(tokens[i % tokens.shape[0]]).copy()[None, :]
        # mask part of the window so the masked set is non-trivial
        t[0, start : start + D.BLOCK_LEN // 2] = vocab.MASK
        tj = jnp.asarray(t, jnp.int32)
        _, _, kc, vc = M.fwd_full_kv(params, tj, use_pallas=False)
        wins.append(tj[0, start : start + D.BLOCK_LEN])
        caches.append((kc, vc))
    kb, vb = M.kv_gather([k for k, _ in caches], [v for _, v in caches])
    win_b = jnp.stack(wins)
    starts_b = jnp.asarray(starts, jnp.int32)
    inf = np.float32(np.inf)
    taus = jnp.asarray([0.5, inf], jnp.float32)      # row 0: threshold rule
    factors = jnp.asarray([inf, 0.9], jnp.float32)   # row 1: factor-max rule
    out = M.fwd_window_accept_batch(
        params, win_b, starts_b, kb, vb, taus, factors,
        jnp.ones((2,), jnp.int32), use_pallas=False,
    )
    conf, arg = M.fwd_window_batch(
        params, win_b, starts_b, kb, vb, use_pallas=False
    )
    for row in range(2):
        want_pairs, want_fb, want_mean = _accept_reference(
            conf[row], arg[row], np.asarray(win_b[row]),
            float(taus[row]), float(factors[row]),
        )
        got_pairs, got_fb, got_mean = _unpack_accept(out, row)
        assert got_pairs == want_pairs, f"row {row}"
        assert got_fb == want_fb
        np.testing.assert_allclose(got_mean, want_mean, atol=1e-5)


def test_accept_padded_rows_contribute_nothing():
    """Bucket-padding rows (row_live == 0) must yield zero commits, a zero
    step mean, and no liveness fallback — even when their padded windows
    are fully masked with confidences that would otherwise accept every
    position. Live rows must be unaffected by the dead rows beside them."""
    bucket, w = 8, D.BLOCK_LEN
    rng = np.random.default_rng(11)
    conf = jnp.asarray(rng.uniform(0.3, 0.95, (bucket, w)), jnp.float32)
    arg = jnp.asarray(rng.integers(4, M.VOCAB, (bucket, w)), jnp.int32)
    # every row fully masked: without row_live, tau=0 accepts everything
    win = jnp.full((bucket, w), vocab.MASK, jnp.int32)
    live = jnp.asarray([1, 1, 0, 0, 0, 0, 0, 0], jnp.int32)
    taus = jnp.zeros((bucket,), jnp.float32)
    factors = jnp.full((bucket,), np.inf, jnp.float32)
    out = M.accept_from_conf(conf, arg, win, taus, factors, live)
    for row in range(bucket):
        pairs, fell_back, mean = _unpack_accept(out, row)
        if int(live[row]):
            want, _, want_mean = _accept_reference(
                conf[row], arg[row], np.asarray(win[row]), 0.0, np.inf
            )
            assert pairs == want, f"live row {row} perturbed by padding"
            np.testing.assert_allclose(mean, want_mean, atol=1e-5)
        else:
            assert pairs == [], f"dead row {row} committed tokens"
            assert not fell_back, f"dead row {row} tripped the fallback"
            assert mean == 0.0, f"dead row {row} leaked a step mean"


def test_accept_fallback_tie_breaks_low():
    """Impossible threshold + equal confidences: the argmax fallback must
    accept exactly the lowest-index masked position (= policy::argmax)."""
    w = D.BLOCK_LEN
    win = np.full((1, w), vocab.MASK, np.int64)
    win[0, 0] = 5  # first position committed: fallback must skip it
    conf = jnp.full((1, w), 0.5, jnp.float32)
    arg = jnp.full((1, w), 7, jnp.int32)
    out = M.accept_from_conf(
        conf, arg, jnp.asarray(win, jnp.int32),
        jnp.asarray([np.inf], jnp.float32), jnp.asarray([np.inf], jnp.float32),
    )
    pairs, fell_back, mean = _unpack_accept(out, 0)
    assert pairs == [(1, 7)], "tie must break to the lowest masked index"
    assert fell_back
    np.testing.assert_allclose(mean, 0.5, atol=1e-6)


def test_accept_spills_across_chunks():
    """A permissive threshold accepts more than one chunk's worth of
    positions; packed entries must spill into later chunk outputs in
    ascending position order."""
    w = D.BLOCK_LEN
    rng = np.random.default_rng(5)
    conf = jnp.asarray(rng.uniform(0.4, 0.9, (1, w)), jnp.float32)
    arg = jnp.asarray(rng.integers(4, M.VOCAB, (1, w)), jnp.int32)
    win = np.full((1, w), vocab.MASK, np.int64)
    win[0, 3] = 9  # one committed position must never be accepted
    out = M.accept_from_conf(
        conf, arg, jnp.asarray(win, jnp.int32),
        jnp.asarray([0.0], jnp.float32), jnp.asarray([np.inf], jnp.float32),
    )
    pairs, fell_back, _ = _unpack_accept(out, 0)
    assert not fell_back
    assert len(pairs) == w - 1 > M.ACCEPT_CHUNK
    assert [p for p, _ in pairs] == [i for i in range(w) if i != 3]
    for (p, t) in pairs:
        assert t == int(arg[0, p])


def test_window_pallas_vs_ref(params, tokens):
    t = tokens[:1]
    _, _, kc, vc = M.fwd_full_kv(params, t, use_pallas=False)
    start = jnp.asarray(D.PROMPT_LEN, jnp.int32)
    win = t[:, D.PROMPT_LEN : D.PROMPT_LEN + D.BLOCK_LEN]
    c1, a1 = M.fwd_window(params, win, start, kc, vc, use_pallas=True)
    c2, a2 = M.fwd_window(params, win, start, kc, vc, use_pallas=False)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_diffusion_loss_finite_and_decreases_on_memorize(params):
    """Loss is finite; a few SGD steps on one batch reduce it (sanity that
    gradients flow through the full graph)."""
    stream = D.training_batch_stream(seed=3, batch_size=4)
    toks, mask = next(stream)
    toks, mask = jnp.asarray(toks), jnp.asarray(mask)
    key = jax.random.PRNGKey(0)
    l0 = M.diffusion_loss(params, toks, mask, key)
    assert bool(jnp.isfinite(l0))
    p = params
    grad_fn = jax.jit(jax.grad(M.diffusion_loss))
    for i in range(5):
        g = grad_fn(p, toks, mask, key)
        p = {k: p[k] - 0.5 * g[k] for k in p}
    l1 = M.diffusion_loss(p, toks, mask, key)
    assert float(l1) < float(l0)


def test_mask_token_changes_predictions(params, tokens):
    """Masking a position must change the model's output there (the mask
    embedding is real signal, not ignored)."""
    t = np.asarray(tokens[:1]).copy()
    c0, _ = M.fwd_conf(params, jnp.asarray(t), use_pallas=False)
    t[0, D.PROMPT_LEN] = vocab.MASK
    c1, _ = M.fwd_conf(params, jnp.asarray(t), use_pallas=False)
    assert not np.allclose(np.asarray(c0), np.asarray(c1))


def test_model_config_complete():
    cfg = M.model_config()
    for key in (
        "d_model", "n_layers", "vocab_size", "seq_len", "prompt_len",
        "gen_len", "block_len", "num_blocks", "mask_id", "eos_id",
        "vocab", "param_order",
    ):
        assert key in cfg
    assert len(cfg["vocab"]) == cfg["vocab_size"]
    assert cfg["prompt_len"] + cfg["gen_len"] == cfg["seq_len"]
    assert cfg["block_len"] * cfg["num_blocks"] == cfg["gen_len"]
