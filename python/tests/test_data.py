"""Synthetic task generators: determinism, well-formedness, encodability."""

import json
import random

import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile import vocab


def test_kb_deterministic():
    assert D.qa_knowledge_base() == D.qa_knowledge_base()
    kb = D.qa_knowledge_base()
    assert len(kb) == 128
    assert set(kb.values()) <= set(D.QA_CLASSES)


@pytest.mark.parametrize("task", D.TASKS)
def test_examples_deterministic(task):
    kb = D.qa_knowledge_base()
    a = [D.make_example(task, kb, random.Random(5)) for _ in range(3)]
    b = [D.make_example(task, kb, random.Random(5)) for _ in range(3)]
    assert a == b


@pytest.mark.parametrize("task", D.TASKS)
def test_examples_fit_layout_and_vocab(task):
    """500 samples per task must encode into the fixed sequence layout."""
    kb = D.qa_knowledge_base()
    rng = random.Random(11)
    for _ in range(500):
        ex = D.make_example(task, kb, rng)
        toks, mask = D.encode_example(ex["prompt"], ex["completion"])
        assert len(toks) == D.SEQ_LEN and len(mask) == D.SEQ_LEN
        assert sum(mask) == D.GEN_LEN
        assert all(0 <= t < vocab.VOCAB_SIZE for t in toks)


def test_math_answers_correct():
    rng = random.Random(2)
    for _ in range(300):
        ex = D.make_math_example(rng)
        expr, val = ex["meta"]["expr"], ex["meta"]["value"]
        assert eval(expr) == val == int(ex["answer"])
        assert 0 <= val <= 99
        assert ex["completion"].endswith(f"#### {val}")


def test_qa_answer_letter_matches_options():
    kb = D.qa_knowledge_base()
    rng = random.Random(3)
    for _ in range(300):
        ex = D.make_qa_example(kb, rng)
        letter, opts = ex["answer"], ex["meta"]["options"]
        assert opts["ABCD".index(letter)] == ex["meta"]["class"]
        assert kb[ex["meta"]["entity"]] == ex["meta"]["class"]


@settings(deadline=None, max_examples=200)
@given(
    op=st.sampled_from(D.CODE_OPS),
    s=st.text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=1, max_size=12),
)
def test_code_ops_properties(op, s):
    out = D.run_code_op(op, s)
    if op == "rev":
        assert D.run_code_op("rev", out) == s  # involution
    elif op == "dup":
        assert len(out) == 2 * len(s) and out[::2] == s
    elif op == "rot1":
        assert len(out) == len(s)
        assert all(
            (ord(b) - ord(a)) % 26 == 1 for a, b in zip(s, out)
        )
    elif op == "swap":
        assert D.run_code_op("swap", out) == s  # involution
    elif op == "drop2":
        assert out == s[::2]


def test_write_datasets(tmp_path):
    D.write_datasets(str(tmp_path), n_eval=10)
    for task in D.TASKS:
        lines = (tmp_path / f"{task}.eval.jsonl").read_text().splitlines()
        assert len(lines) == 10
        for line in lines:
            ex = json.loads(line)
            assert ex["task"] == task
            assert "prompt" in ex and "answer" in ex and "meta" in ex


def test_write_datasets_deterministic(tmp_path):
    d1, d2 = tmp_path / "a", tmp_path / "b"
    D.write_datasets(str(d1), n_eval=5)
    D.write_datasets(str(d2), n_eval=5)
    for task in D.TASKS:
        assert (d1 / f"{task}.eval.jsonl").read_text() == (
            d2 / f"{task}.eval.jsonl"
        ).read_text()


def test_train_stream_shapes():
    stream = D.training_batch_stream(seed=0, batch_size=8)
    toks, mask = next(stream)
    assert toks.shape == (8, D.SEQ_LEN) and mask.shape == (8, D.SEQ_LEN)
    assert toks.dtype.name == "int32"
