"""AOT path: weights.bin container format, HLO text emission, and (when the
build artifacts exist) consistency of the committed artifacts."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _tiny_params():
    # full init is cheap enough
    return M.init_params(1)


def read_weights_bin(path):
    """Reference reader mirroring the Rust loader (format spec test)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(8) == b"OSDTW001"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (dcode,) = struct.unpack("<B", f.read(1))
            assert dcode == 0
            (ndim,) = struct.unpack("<B", f.read(1))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            cnt = int(np.prod(shape)) if ndim else 1
            out[name] = np.frombuffer(
                f.read(4 * cnt), dtype="<f4"
            ).reshape(shape)
        assert f.read() == b""  # no trailing bytes
    return out


def test_weights_bin_roundtrip(tmp_path):
    params = _tiny_params()
    path = str(tmp_path / "w.bin")
    aot.write_weights_bin(path, params)
    back = read_weights_bin(path)
    assert list(back) == M.param_order()
    for k in params:
        np.testing.assert_array_equal(back[k], np.asarray(params[k]))


def test_hlo_text_emission_small_fn():
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_expected_variant_table():
    """The variant table is the runtime's dispatch contract: every window
    bucket must be present or the scheduler silently falls back to b1."""
    names = aot.expected_variants()
    assert len(names) == len(set(names)) == 22
    for b in aot.WINDOW_BATCH_SIZES:
        assert f"fwd_window_b{b}" in names
        assert f"fwd_window_accept_b{b}" in names
        if b > 1:
            assert f"kv_gather_b{b}" in names
    assert {"fwd_window_b8", "fwd_window_b16", "fwd_window_b32"} <= set(names)
    assert aot.WINDOW_BATCH_SIZES == (1, 2, 4, 8, 16, 32)


def test_new_buckets_lower_to_hlo():
    """Lower the widest new bucket (b=8 keeps the test fast; b16/b32 differ
    only in the leading dim) for window, fused-accept, and gather variants."""
    params = _tiny_params()
    b, w, s = 8, aot.WINDOW, M.SEQ_LEN
    dims = (M.N_LAYERS, M.N_HEADS, s, M.HEAD_DIM)
    p_specs = [
        jax.ShapeDtypeStruct(np.asarray(params[k]).shape, jnp.float32)
        for k in M.param_order()
    ]
    win = jax.ShapeDtypeStruct((b, w), jnp.int32)
    starts = jax.ShapeDtypeStruct((b,), jnp.int32)
    kv = jax.ShapeDtypeStruct((b, *dims), jnp.float32)
    fvec = jax.ShapeDtypeStruct((b,), jnp.float32)
    live = jax.ShapeDtypeStruct((b,), jnp.int32)

    def window_fn(*args):
        n = len(p_specs)
        p = dict(zip(M.param_order(), args[:n]))
        return M.fwd_window_batch(p, *args[n : n + 4], use_pallas=True)

    text = aot.to_hlo_text(
        jax.jit(window_fn).lower(*p_specs, win, starts, kv, kv)
    )
    assert "HloModule" in text

    def accept_fn(*args):
        n = len(p_specs)
        p = dict(zip(M.param_order(), args[:n]))
        return M.fwd_window_accept_batch(
            p, *args[n : n + 7], use_pallas=True
        )

    text = aot.to_hlo_text(
        jax.jit(accept_fn).lower(
            *p_specs, win, starts, kv, kv, fvec, fvec, live
        )
    )
    assert "HloModule" in text

    row = jax.ShapeDtypeStruct(dims, jnp.float32)
    text = aot.to_hlo_text(
        jax.jit(
            lambda *rows: M.kv_gather(rows[:b], rows[b:])
        ).lower(*([row] * (2 * b)))
    )
    assert "HloModule" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "model_config.json")),
    reason="artifacts not built",
)
class TestBuiltArtifacts:
    """Validation of the committed build outputs (runs after `make
    artifacts`)."""

    @pytest.fixture(scope="class")
    def cfg(self):
        with open(os.path.join(ART, "model_config.json")) as f:
            return json.load(f)

    def test_config_matches_code(self, cfg):
        mc = M.model_config()
        for k in ("d_model", "n_layers", "vocab_size", "seq_len", "vocab",
                  "param_order", "block_len", "num_blocks"):
            assert cfg[k] == mc[k]

    def test_variant_files_exist(self, cfg):
        assert set(cfg["variants"]) == set(aot.expected_variants())
        for v in cfg["variants"].values():
            p = os.path.join(ART, v["file"])
            assert os.path.exists(p), p
            head = open(p).read(200)
            assert "HloModule" in head

    def test_weights_match_checkpoint(self, cfg):
        w = read_weights_bin(os.path.join(ART, "weights.bin"))
        z = np.load(os.path.join(ART, "checkpoint.npz"))
        for k in cfg["param_order"]:
            np.testing.assert_array_equal(w[k], z[k].astype(np.float32))

    def test_checkpoint_beats_chance(self, cfg):
        """The trained mask predictor must beat chance at reconstructing a
        fully-masked completion's first block — i.e. training actually
        happened (accuracy checks proper live in the Rust eval)."""
        from compile import data as D, train as T, vocab

        params = T.load_checkpoint(os.path.join(ART, "checkpoint.npz"))
        kb = D.qa_knowledge_base()
        import random

        rng = random.Random(99)
        hits = total = 0
        for _ in range(8):
            ex = D.make_example("synth-math", kb, rng)
            toks, _ = D.encode_example(ex["prompt"], ex["completion"])
            noised = list(toks)
            for j in range(D.PROMPT_LEN, D.SEQ_LEN):
                noised[j] = vocab.MASK
            _, arg = M.fwd_conf(
                params, jnp.asarray([noised], jnp.int32), use_pallas=False
            )
            arg = np.asarray(arg[0])
            # score only the real completion chars of the first block
            for j in range(D.PROMPT_LEN, D.PROMPT_LEN + D.BLOCK_LEN):
                if toks[j] != vocab.EOS:
                    total += 1
                    hits += int(arg[j] == toks[j])
        assert total > 0
        # chance is ~1/87; trained single-shot infill should far exceed it
        assert hits / total > 0.15, f"acc {hits}/{total}"
