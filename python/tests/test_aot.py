"""AOT path: weights.bin container format, HLO text emission, and (when the
build artifacts exist) consistency of the committed artifacts."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _tiny_params():
    # full init is cheap enough
    return M.init_params(1)


def read_weights_bin(path):
    """Reference reader mirroring the Rust loader (format spec test)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(8) == b"OSDTW001"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (dcode,) = struct.unpack("<B", f.read(1))
            assert dcode == 0
            (ndim,) = struct.unpack("<B", f.read(1))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            cnt = int(np.prod(shape)) if ndim else 1
            out[name] = np.frombuffer(
                f.read(4 * cnt), dtype="<f4"
            ).reshape(shape)
        assert f.read() == b""  # no trailing bytes
    return out


def test_weights_bin_roundtrip(tmp_path):
    params = _tiny_params()
    path = str(tmp_path / "w.bin")
    aot.write_weights_bin(path, params)
    back = read_weights_bin(path)
    assert list(back) == M.param_order()
    for k in params:
        np.testing.assert_array_equal(back[k], np.asarray(params[k]))


def test_hlo_text_emission_small_fn():
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "model_config.json")),
    reason="artifacts not built",
)
class TestBuiltArtifacts:
    """Validation of the committed build outputs (runs after `make
    artifacts`)."""

    @pytest.fixture(scope="class")
    def cfg(self):
        with open(os.path.join(ART, "model_config.json")) as f:
            return json.load(f)

    def test_config_matches_code(self, cfg):
        mc = M.model_config()
        for k in ("d_model", "n_layers", "vocab_size", "seq_len", "vocab",
                  "param_order", "block_len", "num_blocks"):
            assert cfg[k] == mc[k]

    def test_variant_files_exist(self, cfg):
        assert set(cfg["variants"]) >= {
            "fwd_conf_b1", "fwd_full_kv_b1", "fwd_window_b1", "logits_b1",
        }
        for v in cfg["variants"].values():
            p = os.path.join(ART, v["file"])
            assert os.path.exists(p), p
            head = open(p).read(200)
            assert "HloModule" in head

    def test_weights_match_checkpoint(self, cfg):
        w = read_weights_bin(os.path.join(ART, "weights.bin"))
        z = np.load(os.path.join(ART, "checkpoint.npz"))
        for k in cfg["param_order"]:
            np.testing.assert_array_equal(w[k], z[k].astype(np.float32))

    def test_checkpoint_beats_chance(self, cfg):
        """The trained mask predictor must beat chance at reconstructing a
        fully-masked completion's first block — i.e. training actually
        happened (accuracy checks proper live in the Rust eval)."""
        from compile import data as D, train as T, vocab

        params = T.load_checkpoint(os.path.join(ART, "checkpoint.npz"))
        kb = D.qa_knowledge_base()
        import random

        rng = random.Random(99)
        hits = total = 0
        for _ in range(8):
            ex = D.make_example("synth-math", kb, rng)
            toks, _ = D.encode_example(ex["prompt"], ex["completion"])
            noised = list(toks)
            for j in range(D.PROMPT_LEN, D.SEQ_LEN):
                noised[j] = vocab.MASK
            _, arg = M.fwd_conf(
                params, jnp.asarray([noised], jnp.int32), use_pallas=False
            )
            arg = np.asarray(arg[0])
            # score only the real completion chars of the first block
            for j in range(D.PROMPT_LEN, D.PROMPT_LEN + D.BLOCK_LEN):
                if toks[j] != vocab.EOS:
                    total += 1
                    hits += int(arg[j] == toks[j])
        assert total > 0
        # chance is ~1/87; trained single-shot infill should far exceed it
        assert hits / total > 0.15, f"acc {hits}/{total}"
