"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/values; assert_allclose against ref.py.
This is the CORE correctness signal for the kernels that end up inside the
AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.conf import confidence

# Keep hypothesis deadlines off: interpret-mode pallas is slow per call.
COMMON = dict(deadline=None, max_examples=20)


def rand(rng, shape, dtype, scale=1.0):
    x = rng.standard_normal(shape) * scale
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(**COMMON)
@given(
    heads=st.sampled_from([1, 2, 4]),
    q_tiles=st.integers(1, 4),
    kv_tiles=st.integers(1, 5),
    head_dim=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 5.0]),
)
def test_attention_matches_ref(heads, q_tiles, kv_tiles, head_dim, seed, scale):
    rng = np.random.default_rng(seed)
    q = rand(rng, (heads, 32 * q_tiles, head_dim), jnp.float32, scale)
    k = rand(rng, (heads, 32 * kv_tiles, head_dim), jnp.float32, scale)
    v = rand(rng, (heads, 32 * kv_tiles, head_dim), jnp.float32, scale)
    got = attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    # 1e-4: the online softmax accumulates in a different order than the
    # two-pass reference; at scale=5 (logit std ~25) f32 rounding differs
    # by up to ~5e-5 on isolated elements.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1))
def test_attention_bf16(seed):
    """bf16 inputs: kernel accumulates in f32, so results should agree with
    the f32-accumulating reference at bf16 tolerance."""
    rng = np.random.default_rng(seed)
    q = rand(rng, (2, 64, 16), jnp.bfloat16)
    k = rand(rng, (2, 64, 16), jnp.bfloat16)
    v = rand(rng, (2, 64, 16), jnp.bfloat16)
    got = attention(q, k, v).astype(jnp.float32)
    want = ref.attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-2, rtol=3e-2)


def test_attention_block_shape_mismatch_raises():
    q = jnp.zeros((1, 33, 16), jnp.float32)
    k = jnp.zeros((1, 32, 16), jnp.float32)
    with pytest.raises(ValueError):
        attention(q, k, k)


def test_attention_uniform_values():
    """All-equal K rows -> attention output equals mean of V rows."""
    q = jnp.ones((1, 32, 8), jnp.float32)
    k = jnp.ones((1, 64, 8), jnp.float32)
    v = jnp.tile(jnp.arange(64, dtype=jnp.float32)[None, :, None], (1, 1, 8))
    got = attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), 31.5, atol=1e-4)


def test_attention_one_hot_softmax():
    """A single dominant key should receive ~all attention mass."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(np.full((1, 32, 8), 3.0), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 8)) * 0.01, jnp.float32)
    k = k.at[0, 17].set(30.0)  # dominant key aligned with all queries
    v = rand(rng, (1, 64, 8), jnp.float32)
    got = attention(q, k, v)
    want = jnp.tile(v[0, 17][None, None, :], (1, 32, 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


# ---------------------------------------------------------------------------
# confidence
# ---------------------------------------------------------------------------

@settings(**COMMON)
@given(
    seq_tiles=st.integers(1, 5),
    vocab=st.sampled_from([5, 64, 87, 128, 130, 200]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_confidence_matches_ref(seq_tiles, vocab, seed, scale):
    rng = np.random.default_rng(seed)
    x = rand(rng, (32 * seq_tiles, vocab), jnp.float32, scale)
    c, a = confidence(x)
    cr, ar = ref.confidence_ref(x)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-6, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))


def test_confidence_tie_breaks_low_id():
    """Exactly tied maxima resolve to the lowest vocab id (jnp.argmax
    semantics, which the Rust side relies on for determinism)."""
    x = np.zeros((32, 87), np.float32)
    x[:, 10] = 5.0
    x[:, 70] = 5.0  # tie across two vocab tiles
    c, a = confidence(jnp.asarray(x))
    assert np.all(np.asarray(a) == 10)


def test_confidence_peaked_distribution():
    """A very peaked row must give conf ~ 1."""
    x = np.full((32, 87), -20.0, np.float32)
    x[:, 3] = 20.0
    c, a = confidence(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(c), 1.0, atol=1e-6)
    assert np.all(np.asarray(a) == 3)


def test_confidence_uniform_distribution():
    """Uniform logits -> conf = 1/vocab."""
    x = jnp.zeros((32, 87), jnp.float32)
    c, _ = confidence(x)
    np.testing.assert_allclose(np.asarray(c), 1.0 / 87, rtol=1e-5)


def test_confidence_extreme_logits_finite():
    rng = np.random.default_rng(1)
    x = rand(rng, (32, 87), jnp.float32, 300.0)
    c, _ = confidence(x)
    assert np.all(np.isfinite(np.asarray(c)))
    assert np.all((np.asarray(c) > 0) & (np.asarray(c) <= 1.0 + 1e-6))


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

from compile.kernels.layernorm import layernorm  # noqa: E402


@settings(**COMMON)
@given(
    row_tiles=st.integers(1, 5),
    d=st.sampled_from([8, 64, 96, 256]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 50.0]),
)
def test_layernorm_matches_ref(row_tiles, d, seed, scale):
    rng = np.random.default_rng(seed)
    x = rand(rng, (32 * row_tiles, d), jnp.float32, scale)
    g = rand(rng, (d,), jnp.float32)
    b = rand(rng, (d,), jnp.float32)
    got = layernorm(x, g, b)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_layernorm_output_stats():
    """With identity affine, each row must have ~zero mean, ~unit variance."""
    rng = np.random.default_rng(3)
    x = rand(rng, (32, 64), jnp.float32, 7.0)
    y = np.asarray(layernorm(x, jnp.ones(64), jnp.zeros(64)))
    np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.var(axis=-1), 1.0, atol=1e-3)


def test_layernorm_shape_validation():
    x = jnp.zeros((32, 8), jnp.float32)
    with pytest.raises(ValueError):
        layernorm(x, jnp.ones(9), jnp.zeros(9))
    with pytest.raises(ValueError):
        layernorm(jnp.zeros((33, 8), jnp.float32), jnp.ones(8), jnp.zeros(8))


def test_layernorm_constant_rows_finite():
    """A constant row has zero variance; eps must keep the output finite."""
    x = jnp.full((32, 16), 3.0, jnp.float32)
    y = np.asarray(layernorm(x, jnp.ones(16), jnp.zeros(16)))
    assert np.all(np.isfinite(y))
    np.testing.assert_allclose(y, 0.0, atol=1e-3)
