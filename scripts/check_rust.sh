#!/usr/bin/env bash
# Consolidated Rust CI entry point: one script, one source of truth for the
# flags, shared by every workflow job and runnable locally.
#
#     scripts/check_rust.sh [fmt|clippy|build|test|bench-gate|fleet-smoke|all]
#
# Modes map 1:1 onto the CI jobs in .github/workflows/ci.yml:
#   fmt         cargo fmt --all --check
#   clippy      cargo clippy --workspace --all-targets -- -D warnings
#   build       cargo build --release --workspace --all-targets
#   test        cargo build --benches + cargo test -q --workspace
#   bench-gate  serving_load smoke bench + bench_diff trajectory gate
#   fleet-smoke supervisor + 2 sim replicas, SIGKILL one, assert failover
#   all         everything above, in that order (default)
#
# Containers without a Rust toolchain (artifact-only dev images) get a
# clear diagnostic instead of a bash stack trace; set ALLOW_MISSING_RUST=1
# to turn that into a skip (exit 0) for mixed-language pre-commit hooks.

set -euo pipefail
cd "$(dirname "$0")/.."

mode="${1:-all}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "check_rust: no cargo on PATH — install a Rust toolchain" \
        "(https://rustup.rs) to run the '$mode' checks" >&2
    if [[ "${ALLOW_MISSING_RUST:-0}" == "1" ]]; then
        echo "check_rust: ALLOW_MISSING_RUST=1 set, skipping" >&2
        exit 0
    fi
    exit 1
fi

run() {
    echo "+ $*" >&2
    "$@"
}

do_fmt()    { run cargo fmt --all --check; }
do_clippy() { run cargo clippy --workspace --all-targets -- -D warnings; }
do_build()  { run cargo build --release --workspace --all-targets; }
do_test() {
    # benches are harness = false / test = false, so `cargo test` alone
    # never compiles them — build them explicitly so the bench binaries
    # can't bit-rot
    run cargo build --benches --workspace
    run cargo test -q --workspace
}
do_bench_gate() {
    # steps-capped smoke run on the analytic simulator (no artifacts in
    # CI); the elision A/B and shared-prefix sections self-assert token
    # identity, then bench_diff gates tokens/s against the committed
    # trajectory snapshot (bench/trajectory/README.md)
    run cargo bench --bench serving_load -- --smoke --seed 7 --json BENCH_serving.json
    run python3 scripts/bench_diff.py bench/trajectory/BENCH_serving.json BENCH_serving.json
}
do_fleet_smoke() {
    # end-to-end process-tier drill (DESIGN.md §16): start a supervisor
    # with two sim replicas and a router, take a baseline completion,
    # SIGKILL one replica, assert token-identical failover and a respawn
    # on the original port, then tear the fleet down
    run cargo run --release -q -- fleet smoke
}

case "$mode" in
    fmt)         do_fmt ;;
    clippy)      do_clippy ;;
    build)       do_build ;;
    test)        do_test ;;
    bench-gate)  do_bench_gate ;;
    fleet-smoke) do_fleet_smoke ;;
    all)         do_fmt; do_clippy; do_build; do_test; do_bench_gate; do_fleet_smoke ;;
    *)
        echo "check_rust: unknown mode '$mode'" \
            "(fmt|clippy|build|test|bench-gate|fleet-smoke|all)" >&2
        exit 2
        ;;
esac
