#!/usr/bin/env python3
"""Gate a fresh serving_load bench run against the committed trajectory.

Usage:
    python3 scripts/bench_diff.py BASELINE.json CURRENT.json [--threshold 0.10]

Both files are the schema-2 JSON emitted by
`cargo bench --bench serving_load -- --smoke --json OUT.json`.
Rows are matched by (policy, cache, residency, rate); a matched row whose
tokens/s dropped by more than the threshold fails the gate. Latency
percentiles are reported but never gated — shared CI runners are too
noisy for that.

Provenance rule: a baseline with "provenance": "seed" (the bootstrap
snapshot committed before any CI runner measured one) reports
regressions as warnings and always exits 0. Replace it with a measured
snapshot (see bench/trajectory/README.md) to arm the gate.

Step-elision rows (cache "elide-on"/"elide-off") additionally carry
steps_executed/steps_elided and are checked for self-consistency in BOTH
artifacts: the elide-on row must elide at least one step and execute
strictly fewer passes than its matched elide-off row. These run on the
deterministic analytic simulator, so violations are hard errors even
under a seed baseline.

Predictive-admission rows (cache "fifo"/"predictive", DESIGN.md §15)
carry predicted_steps_p50 / forecast_abs_err_p95 / shed_rate and get the
same treatment: the forecast error must be a finite non-negative number,
the median forecast a positive pass count, and the shed rate exactly 0 —
the bench never configures a watermark or SLO, so any shed is a bug, not
noise. Hard errors in BOTH artifacts, even under a seed baseline.

Fleet-tier rows (cache "fleet-steady"/"fleet-failover", DESIGN.md §16)
come in pairs: the same burst trace routed through the process-tier
router with both replicas up vs with one torn down mid-trace. Failover
is pure rerouting on the deterministic simulator, so both rows must
complete every request (ok == n, zero drops even with a replica dying
mid-trace) and report a positive tokens/s; the steady row must shed
nothing. Hard errors in BOTH artifacts, even under a seed baseline.
(Token identity across the arms is asserted inside the bench itself —
completions never reach the JSON artifact.)

Exit codes: 0 pass/warn-only, 1 regression, 2 usage or schema error.
Stdlib only.
"""

from __future__ import annotations

import argparse
import json
import math
import sys

SCHEMA = 2
KEY_FIELDS = ("policy", "cache", "residency", "rate")
REPORT_FIELDS = ("tokens_per_sec", "p50_ms", "p95_ms", "p99_ms", "ttft_p95_ms")


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        sys.exit(f"error: cannot read {path}: {e}")
    if doc.get("bench") != "serving_load":
        sys.exit(f"error: {path} is not a serving_load artifact")
    if doc.get("schema") != SCHEMA:
        sys.exit(
            f"error: {path} has schema {doc.get('schema')!r}, expected {SCHEMA};"
            " regenerate both artifacts with the same bench binary"
        )
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        sys.exit(f"error: {path} has no rows")
    return doc


def key(row):
    try:
        return tuple(row[f] for f in KEY_FIELDS)
    except KeyError as e:
        sys.exit(f"error: row missing {e}: {row}")


def fmt_key(k):
    policy, cache, residency, rate = k
    return f"{policy} cache={cache}:{residency} @{rate}rps"


def check_elision(doc, path):
    """Self-consistency of step-elision A/B rows (cache elide-on/elide-off).

    The elision comparator runs on the deterministic analytic simulator, so
    these are hard invariants, not runner-noise measurements: the elide-on
    row must record strictly fewer executed passes than its matched
    elide-off row, with a nonzero elided count. Violations are errors even
    under a "seed" baseline. Artifacts predating the elision rows (no
    elide-* cache labels) pass vacuously.
    """
    problems = []
    rows = {key(r): r for r in doc["rows"]}
    for k, on in rows.items():
        policy, cache, residency, rate = k
        if cache != "elide-on":
            continue
        off = rows.get((policy, "elide-off", residency, rate))
        if off is None:
            problems.append(f"{path}: {fmt_key(k)} has no matching elide-off row")
            continue
        missing = [
            f"{path}: {label} row for {policy} @{rate}rps is missing {field}"
            for field in ("steps_executed", "steps_elided")
            for row, label in ((on, "elide-on"), (off, "elide-off"))
            if field not in row
        ]
        if missing:
            problems.extend(missing)
            continue
        if float(on["steps_elided"]) <= 0:
            problems.append(
                f"{path}: {fmt_key(k)} elided no steps — the planner never fired"
            )
        if float(on["steps_executed"]) >= float(off["steps_executed"]):
            problems.append(
                f"{path}: {fmt_key(k)} executed {on['steps_executed']} passes "
                f">= elide-off's {off['steps_executed']} — elision saved nothing"
            )
    return problems


def check_predictive(doc, path):
    """Self-consistency of FIFO-vs-predictive admission rows (cache
    "fifo"/"predictive", DESIGN.md §15).

    The admission A/B runs on the deterministic analytic simulator with no
    shed watermark or SLO budget configured, so these are hard invariants,
    not runner-noise measurements: both rows must carry the
    predictive-scheduling fields, forecast_abs_err_p95 must be a finite
    non-negative number (an empty forecast-error histogram serializes as
    null — the cost model never scored a retirement), predicted_steps_p50
    must be a positive pass count, and shed_rate must be exactly 0 — the
    guardrails firing with nothing armed is a bug. Violations are errors
    even under a "seed" baseline. Artifacts predating the predictive rows
    (no fifo/predictive cache labels) pass vacuously.
    """
    problems = []
    rows = {key(r): r for r in doc["rows"]}
    fields = ("predicted_steps_p50", "forecast_abs_err_p95", "shed_rate")
    for k, pred in rows.items():
        policy, cache, residency, rate = k
        if cache != "predictive":
            continue
        fifo = rows.get((policy, "fifo", residency, rate))
        if fifo is None:
            problems.append(f"{path}: {fmt_key(k)} has no matching fifo row")
            continue
        missing = [
            f"{path}: {label} row for {policy} @{rate}rps has no numeric {field}"
            for field in fields
            for row, label in ((pred, "predictive"), (fifo, "fifo"))
            if not isinstance(row.get(field), (int, float))
        ]
        if missing:
            problems.extend(missing)
            continue
        for row, label in ((pred, "predictive"), (fifo, "fifo")):
            where = f"{path}: {label} row for {policy} @{rate}rps"
            err = float(row["forecast_abs_err_p95"])
            if not math.isfinite(err) or err < 0:
                problems.append(
                    f"{where} has forecast_abs_err_p95 {err!r} — must be a"
                    " finite non-negative pass count"
                )
            p50 = float(row["predicted_steps_p50"])
            if not math.isfinite(p50) or p50 <= 0:
                problems.append(
                    f"{where} has predicted_steps_p50 {p50!r} — forecasts"
                    " were never stamped at admission"
                )
            if float(row["shed_rate"]) != 0.0:
                problems.append(
                    f"{where} shed {row['shed_rate']} of requests with no"
                    " watermark or SLO configured"
                )
    return problems


def check_fleet(doc, path):
    """Self-consistency of fleet-tier A/B rows (cache "fleet-steady"/
    "fleet-failover", DESIGN.md §16).

    The fleet arms run the same deterministic burst trace through the
    process-tier router, once with both sim replicas up and once with
    replica 0 killed mid-trace. Failover is pure rerouting — the client's
    retries plus the router's transport-failure retries must absorb the
    death entirely — so zero dropped requests (ok == n) is a hard
    invariant of BOTH rows, not a throughput measurement: violations are
    errors even under a "seed" baseline. The steady row additionally must
    shed nothing (no replica died, the shed guardrail firing is a bug).
    Artifacts predating the fleet rows (no fleet-* cache labels) pass
    vacuously.
    """
    problems = []
    rows = {key(r): r for r in doc["rows"]}
    for k, failover in rows.items():
        policy, cache, residency, rate = k
        if cache != "fleet-failover":
            continue
        steady = rows.get((policy, "fleet-steady", residency, rate))
        if steady is None:
            problems.append(
                f"{path}: {fmt_key(k)} has no matching fleet-steady row"
            )
            continue
        for row, label in ((failover, "fleet-failover"), (steady, "fleet-steady")):
            where = f"{path}: {label} row for {policy} @{rate}rps"
            missing = [
                f for f in ("ok", "n", "tokens_per_sec", "shed_rate")
                if not isinstance(row.get(f), (int, float))
            ]
            if missing:
                problems.append(f"{where} has no numeric {', '.join(missing)}")
                continue
            if float(row["ok"]) != float(row["n"]):
                why = (
                    "retries did not absorb the replica death"
                    if label == "fleet-failover"
                    else "requests went missing with both replicas up"
                )
                problems.append(
                    f"{where} dropped requests: ok {row['ok']} != n"
                    f" {row['n']} — {why}"
                )
            if float(row["tokens_per_sec"]) <= 0:
                problems.append(
                    f"{where} reports tokens_per_sec {row['tokens_per_sec']}"
                    " — the fleet arm never served"
                )
        if float(steady.get("shed_rate", 0)) != 0.0:
            problems.append(
                f"{path}: fleet-steady row for {policy} @{rate}rps shed"
                f" {steady['shed_rate']} of requests with both replicas up"
            )
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="max tolerated fractional tokens/s drop (default 0.10)",
    )
    args = ap.parse_args(argv)

    base = load(args.baseline)
    cur = load(args.current)
    warn_only = base.get("provenance") == "seed"

    hard_problems = (
        check_elision(base, args.baseline)
        + check_elision(cur, args.current)
        + check_predictive(base, args.baseline)
        + check_predictive(cur, args.current)
        + check_fleet(base, args.baseline)
        + check_fleet(cur, args.current)
    )
    for p in hard_problems:
        print(f"error: {p}")

    base_rows = {key(r): r for r in base["rows"]}
    cur_rows = {key(r): r for r in cur["rows"]}

    matched = sorted(set(base_rows) & set(cur_rows))
    if not matched:
        sys.exit("error: no rows in common between baseline and current")
    for k in sorted(set(base_rows) - set(cur_rows)):
        print(f"note: baseline row not in current run: {fmt_key(k)}")
    for k in sorted(set(cur_rows) - set(base_rows)):
        print(f"note: new row with no baseline: {fmt_key(k)}")

    regressions = []
    for k in matched:
        b, c = base_rows[k], cur_rows[k]
        b_tps, c_tps = float(b["tokens_per_sec"]), float(c["tokens_per_sec"])
        delta = (c_tps - b_tps) / b_tps if b_tps > 0 else 0.0
        status = "ok"
        if delta < -args.threshold:
            status = "WARN" if warn_only else "FAIL"
            regressions.append((k, b_tps, c_tps, delta))
        extra = " ".join(
            f"{f}={float(c[f]):.1f}" for f in REPORT_FIELDS[1:] if f in c
        )
        print(
            f"[{status}] {fmt_key(k)}: tokens/s {b_tps:.1f} -> {c_tps:.1f} "
            f"({delta:+.1%}) {extra}"
        )

    print(
        f"\n{len(matched)} row(s) compared, {len(regressions)} beyond "
        f"-{args.threshold:.0%} tokens/s"
    )
    if hard_problems:
        # deterministic-sim invariants, not throughput noise: never waived
        # by a seed baseline
        print("bench self-consistency FAILED")
        return 1
    if regressions and warn_only:
        print(
            "baseline provenance is 'seed' (bootstrap values, never measured"
            " on this runner): warnings only, gate not armed"
        )
        return 0
    if regressions:
        print("regression gate FAILED")
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
